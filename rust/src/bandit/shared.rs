//! Shared TapOut controller for the multi-worker serving engine
//! (DESIGN.md §2).
//!
//! The paper's method is *online*: the bandit keeps improving as it
//! observes more verification outcomes. In a serving engine that only
//! pays off if every concurrent request feeds the same learner, so the
//! bandit state lives here — one process-wide [`SharedController`] — while
//! each decode worker drives its own [`SessionController`], a lightweight
//! per-thread handle implementing [`DecodeControl`].
//!
//! Split of state (mirrors `SeqBandit`/`TokenBandit`, which remain the
//! single-threaded implementations used by the harness):
//!
//! * **shared, locked** — the bandit(s): arm value estimates and play
//!   counts. Touched only at session boundaries: one `select` at
//!   session start, one `update` at verification. Both are a few float
//!   ops under a `Mutex`, never held across model execution.
//! * **per-worker, lock-free** — the arm-policy pool (stop heuristics)
//!   and the current-arm / chosen-arms bookkeeping. Policies are cheap
//!   deterministic per-session state machines; giving each worker its own
//!   pool keeps the per-token `should_stop` hot path free of any lock at
//!   sequence granularity.
//!
//! Atomicity argument: a session's lifecycle is select(arm) → … →
//! update(arm, r). Workers record the selected arm *locally*, so an
//! interleaved session on another worker can never redirect the reward
//! (the seed engine's `SeqBandit.current` field would have been a data
//! race here). UCB1/UCB-Tuned/TS are order-agnostic over bounded reward
//! streams, so interleaving different sessions' select/update pairs
//! preserves convergence — both regret analyses only need each arm's
//! reward tally to be exact, which the per-update lock guarantees.
//!
//! Batched verification (docs/ARCHITECTURE.md §4) changes *when* rewards
//! land, not *how*: a worker's `on_verify` fires once its session's rows
//! scatter back from the batcher, so the shared bandit absorbs a burst of
//! updates per batched forward — one per coalesced session — instead of
//! one per private forward. By the same order-agnosticism, that timing
//! shift is invisible to the learner; play-count conservation across
//! batch windows is pinned by `rust/tests/engine_batched.rs`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::policies::pool::{default_arms, multi_threshold_arms};
use crate::policies::BoxedPolicy;
use crate::signals::TokenSignals;
use crate::spec::{DecodeControl, MethodSpec};
use crate::util::Rng;

use super::{make_bandit, BoxedBandit, Reward};

/// Sequence-granularity shared state: the global bandit over the arm
/// pool, plus lazily created per-`"{tenant}#{drafter}"` bandits for
/// tenant-keyed sessions (docs/ARCHITECTURE.md §17). The global/default
/// context (`tenant == "", drafter == 0`) uses exactly the pre-pool code
/// path — same bandit, same single RNG draw — so default traffic stays
/// byte-identical to main.
struct SeqShared {
    bandit: Mutex<BoxedBandit>,
    reward: Reward,
    kind: String,
    n_arms: usize,
    tenants: Mutex<HashMap<String, BoxedBandit>>,
}

impl SeqShared {
    /// Select from the `"{tenant}#{drafter}"` bandit, creating it on
    /// first sight seeded with one pseudo-observation per arm at the
    /// global posterior mean — the hierarchical prior: an unseen tenant's
    /// first selection is the global best arm, and its own evidence takes
    /// over from there. Lock order is tenants → global everywhere.
    fn select_keyed(&self, tenant: &str, drafter: usize, rng: &mut Rng) -> usize {
        let key = format!("{tenant}#{drafter}");
        let mut tenants = self.tenants.lock().unwrap();
        let b = tenants.entry(key).or_insert_with(|| {
            let mut b = make_bandit(&self.kind, self.n_arms);
            let g = self.bandit.lock().unwrap();
            if g.counts().iter().sum::<u64>() > 0 {
                for (a, v) in g.values().iter().enumerate() {
                    b.update(a, v.clamp(0.0, 1.0));
                }
            }
            b
        });
        b.select(rng)
    }

    /// Land a keyed session's reward in **both** the keyed bandit and the
    /// global aggregate — the global ledger keeps Σ counts == updates for
    /// the conservation oracle, and keeps the prior for future tenants
    /// current.
    fn update_keyed(&self, tenant: &str, drafter: usize, arm: usize, r: f64) {
        let key = format!("{tenant}#{drafter}");
        let mut tenants = self.tenants.lock().unwrap();
        let b = tenants
            .entry(key)
            .or_insert_with(|| make_bandit(&self.kind, self.n_arms));
        b.update(arm, r);
        self.bandit.lock().unwrap().update(arm, r);
    }
}

/// Token-granularity shared state: an independent bandit per draft
/// position, grown lazily (same protocol as `TokenBandit`).
struct TokenShared {
    kind: String,
    n_arms: usize,
    bandits: Mutex<Vec<BoxedBandit>>,
}

impl TokenShared {
    /// Select an arm for draft position `idx`, growing the ladder on
    /// demand.
    fn select_at(&self, idx: usize, rng: &mut Rng) -> usize {
        let mut bandits = self.bandits.lock().unwrap();
        while bandits.len() <= idx {
            bandits.push(make_bandit(&self.kind, self.n_arms));
        }
        bandits[idx].select(rng)
    }
}

/// Process-wide controller handle: owns the shared bandit state and mints
/// per-worker [`SessionController`]s. Cheap to clone-by-`Arc` internally;
/// the engine keeps one and calls [`SharedController::session`] per
/// worker thread.
pub struct SharedController {
    method: MethodSpec,
    gamma_max: usize,
    seq: Option<Arc<SeqShared>>,
    token: Option<Arc<TokenShared>>,
    /// drafting sessions started (select events) across all workers
    sessions: Arc<AtomicU64>,
    /// verification outcomes absorbed (update events) across all workers
    updates: Arc<AtomicU64>,
}

fn arm_pool(multi: bool) -> Vec<BoxedPolicy> {
    if multi {
        multi_threshold_arms()
    } else {
        default_arms()
    }
}

impl SharedController {
    /// Build the process-wide shared state for `method` (no state for
    /// stateless methods — their sessions get private controllers).
    pub fn new(method: &MethodSpec, gamma_max: usize) -> SharedController {
        let (seq, token) = match method {
            MethodSpec::SeqBandit { kind, reward, multi_arms } => {
                let n = arm_pool(*multi_arms).len();
                let shared = SeqShared {
                    bandit: Mutex::new(make_bandit(kind, n)),
                    reward: *reward,
                    kind: kind.clone(),
                    n_arms: n,
                    tenants: Mutex::new(HashMap::new()),
                };
                (Some(Arc::new(shared)), None)
            }
            MethodSpec::TokenBandit { kind, multi_arms } => {
                let shared = TokenShared {
                    kind: kind.clone(),
                    n_arms: arm_pool(*multi_arms).len(),
                    bandits: Mutex::new(Vec::new()),
                };
                (None, Some(Arc::new(shared)))
            }
            _ => (None, None),
        };
        SharedController {
            method: method.clone(),
            gamma_max,
            seq,
            token,
            sessions: Arc::new(AtomicU64::new(0)),
            updates: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Mint the per-worker session handle. Bandit methods share this
    /// controller's bandit; stateless methods (Static-k, tuned single
    /// policies) get a private `StopController` — they have no state worth
    /// sharing, and per-worker isolation keeps them contention-free.
    pub fn session(&self) -> Result<SessionController> {
        let mode = match &self.method {
            MethodSpec::SeqBandit { multi_arms, .. } => Mode::Seq {
                shared: self.seq.clone().expect("seq state exists for seq methods"),
                arms: arm_pool(*multi_arms),
                current: 0,
            },
            MethodSpec::TokenBandit { multi_arms, .. } => Mode::Token {
                shared: self.token.clone().expect("token state exists for token methods"),
                arms: arm_pool(*multi_arms),
                chosen: Vec::new(),
            },
            other => Mode::Local(other.build(self.gamma_max)?),
        };
        Ok(SessionController {
            mode,
            gamma_max: self.gamma_max,
            sessions: self.sessions.clone(),
            updates: self.updates.clone(),
            tenant: String::new(),
            drafter: 0,
        })
    }

    /// Is there actually shared learning state (a bandit method)?
    pub fn is_shared(&self) -> bool {
        self.seq.is_some() || self.token.is_some()
    }

    /// Paper-style label of the configured method.
    pub fn method_label(&self) -> String {
        self.method.label()
    }

    /// Total drafting sessions observed across all workers since boot —
    /// the inter-request carryover readout (a fresh-per-request controller
    /// would reset this).
    pub fn sessions(&self) -> u64 {
        self.sessions.load(Ordering::Relaxed)
    }

    /// Total bandit reward updates absorbed across all workers.
    pub fn updates(&self) -> u64 {
        self.updates.load(Ordering::Relaxed)
    }

    /// Per-arm play counts. Seq: the shared bandit's counts. Token:
    /// summed over the per-position ladder. `None` for stateless methods.
    pub fn arm_counts(&self) -> Option<Vec<u64>> {
        if let Some(seq) = &self.seq {
            return Some(seq.bandit.lock().unwrap().counts());
        }
        if let Some(token) = &self.token {
            let bandits = token.bandits.lock().unwrap();
            let mut sum = vec![0u64; token.n_arms];
            for b in bandits.iter() {
                for (s, c) in sum.iter_mut().zip(b.counts()) {
                    *s += c;
                }
            }
            return Some(sum);
        }
        None
    }

    /// Per-arm value estimates (Seq granularity only — the Figs. 5-6
    /// readout).
    pub fn arm_values(&self) -> Option<Vec<f64>> {
        self.seq.as_ref().map(|s| s.bandit.lock().unwrap().values())
    }

    /// Names of the arms in play (bandit methods only).
    pub fn arm_names(&self) -> Option<Vec<String>> {
        match &self.method {
            MethodSpec::SeqBandit { multi_arms, .. }
            | MethodSpec::TokenBandit { multi_arms, .. } => {
                Some(arm_pool(*multi_arms).iter().map(|a| a.name()).collect())
            }
            _ => None,
        }
    }

    /// Per-key policy-bandit readout for `/metrics` (`"{tenant}#{drafter}"`
    /// → per-arm counts/values), sorted for deterministic rendering.
    /// Empty for token/stateless methods or before any keyed session ran;
    /// the legacy flat fields stay the global-tenant view
    /// (docs/OPERATIONS.md).
    pub fn tenant_arm_snapshot(&self) -> Vec<(String, Vec<u64>, Vec<f64>)> {
        let Some(seq) = &self.seq else { return Vec::new() };
        let tenants = seq.tenants.lock().unwrap();
        let mut out: Vec<(String, Vec<u64>, Vec<f64>)> =
            tenants.iter().map(|(k, b)| (k.clone(), b.counts(), b.values())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

enum Mode {
    /// Stateless methods: a private per-worker controller.
    Local(crate::spec::StopController),
    /// Sequence-level bandit: shared learner, per-worker arm pool.
    Seq { shared: Arc<SeqShared>, arms: Vec<BoxedPolicy>, current: usize },
    /// Token-level bandit ladder: shared learners, per-worker arm pool.
    Token { shared: Arc<TokenShared>, arms: Vec<BoxedPolicy>, chosen: Vec<usize> },
}

/// Per-worker controller handle: owned (`&mut`) by exactly one decode
/// worker, so everything outside the tiny bandit critical sections is
/// lock-free. Implements [`DecodeControl`], making it interchangeable
/// with `StopController` inside `spec::generate`.
pub struct SessionController {
    mode: Mode,
    gamma_max: usize,
    sessions: Arc<AtomicU64>,
    updates: Arc<AtomicU64>,
    /// tenant key of the request being decoded (`""` = global tenant)
    tenant: String,
    /// pooled drafter the current round routes through (0 = pool head)
    drafter: usize,
}

impl DecodeControl for SessionController {
    fn session_start(&mut self, rng: &mut Rng) {
        match &mut self.mode {
            Mode::Local(c) => c.session_start(rng),
            Mode::Seq { shared, arms, current } => {
                // atomic select: the chosen arm is recorded locally, so a
                // concurrent session can never redirect this one's reward.
                // The global/default context takes exactly the pre-pool
                // path (same bandit, same single RNG draw); keyed contexts
                // select from their own posterior seeded off the global.
                *current = if self.tenant.is_empty() && self.drafter == 0 {
                    shared.bandit.lock().unwrap().select(rng)
                } else {
                    shared.select_keyed(&self.tenant, self.drafter, rng)
                };
                arms[*current].on_session_start();
            }
            Mode::Token { arms, chosen, .. } => {
                chosen.clear();
                for a in arms.iter_mut() {
                    a.on_session_start();
                }
            }
        }
        self.sessions.fetch_add(1, Ordering::Relaxed);
    }

    fn should_stop(&mut self, sig: &TokenSignals, idx: usize, rng: &mut Rng) -> bool {
        match &mut self.mode {
            Mode::Local(c) => c.should_stop(sig, idx, rng),
            Mode::Seq { arms, current, .. } => arms[*current].should_stop(sig, idx),
            Mode::Token { shared, arms, chosen } => {
                let arm = shared.select_at(idx, rng);
                debug_assert_eq!(chosen.len(), idx);
                chosen.push(arm);
                arms[arm].should_stop(sig, idx)
            }
        }
    }

    fn on_verify(&mut self, accepted: usize, drafted: usize) {
        match &mut self.mode {
            Mode::Local(c) => c.on_verify(accepted, drafted),
            Mode::Seq { shared, arms, current } => {
                let r = shared.reward.compute(accepted, drafted, self.gamma_max);
                if self.tenant.is_empty() && self.drafter == 0 {
                    shared.bandit.lock().unwrap().update(*current, r);
                } else {
                    shared.update_keyed(&self.tenant, self.drafter, *current, r);
                }
                // only the arm that drove the session sees the outcome
                arms[*current].on_verify(accepted, drafted);
            }
            Mode::Token { shared, arms, chosen } => {
                {
                    let mut bandits = shared.bandits.lock().unwrap();
                    for i in 0..drafted.min(chosen.len()) {
                        let r = if i < accepted { 1.0 } else { 0.0 };
                        bandits[i].update(chosen[i], r);
                    }
                }
                for a in arms.iter_mut() {
                    a.on_verify(accepted, drafted);
                }
            }
        }
        self.updates.fetch_add(1, Ordering::Relaxed);
    }

    fn on_abort(&mut self) {
        match &mut self.mode {
            Mode::Local(_) => {}
            Mode::Seq { shared, current, .. } => {
                // the aborted round accepted nothing: a zero reward keeps
                // Σ arm counts == updates == sessions conserved under
                // faults, and UCB/TS remain sound over bounded rewards
                if self.tenant.is_empty() && self.drafter == 0 {
                    shared.bandit.lock().unwrap().update(*current, 0.0);
                } else {
                    shared.update_keyed(&self.tenant, self.drafter, *current, 0.0);
                }
            }
            Mode::Token { shared, chosen, .. } => {
                let mut bandits = shared.bandits.lock().unwrap();
                for (i, &arm) in chosen.iter().enumerate() {
                    bandits[i].update(arm, 0.0);
                }
                chosen.clear();
            }
        }
        self.updates.fetch_add(1, Ordering::Relaxed);
    }

    fn reset_request(&mut self) {
        match &mut self.mode {
            Mode::Local(c) => c.reset_request(),
            // per-request policy state resets; the *shared* bandit memory
            // persists across requests and workers (the online setting)
            Mode::Seq { arms, .. } => {
                for a in arms.iter_mut() {
                    a.reset();
                }
            }
            Mode::Token { arms, chosen, .. } => {
                for a in arms.iter_mut() {
                    a.reset();
                }
                chosen.clear();
            }
        }
    }

    fn current_arm(&self) -> Option<usize> {
        match &self.mode {
            Mode::Local(c) => c.current_arm(),
            Mode::Seq { current, .. } => Some(*current),
            Mode::Token { .. } => None,
        }
    }

    fn set_context(&mut self, tenant: &str, drafter: usize) {
        // Token granularity stays global-only: its per-position ladder is
        // already high-variance, and splitting it per tenant would starve
        // every cell — the drafter layer above still adapts per tenant.
        // Seq sessions route through the keyed posterior from the next
        // session_start on.
        if tenant != self.tenant {
            self.tenant = tenant.to_string();
        }
        self.drafter = drafter;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(s: &str) -> MethodSpec {
        MethodSpec::parse(s, ".").unwrap()
    }

    #[test]
    fn shared_seq_bandit_converges_across_threads() {
        let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
        let n_threads = 4;
        let per_thread = 200;
        std::thread::scope(|scope| {
            for t in 0..n_threads {
                let mut session = ctrl.session().unwrap();
                scope.spawn(move || {
                    let mut rng = Rng::new(100 + t as u64);
                    for _ in 0..per_thread {
                        session.session_start(&mut rng);
                        // arm 1 is the rewarding arm, as in the SeqBandit
                        // single-threaded convergence test
                        let (acc, dr) =
                            if session.current_arm() == Some(1) { (5, 6) } else { (1, 6) };
                        session.on_verify(acc, dr);
                    }
                });
            }
        });
        let total = (n_threads * per_thread) as u64;
        assert_eq!(ctrl.sessions(), total);
        assert_eq!(ctrl.updates(), total);
        let counts = ctrl.arm_counts().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), total, "{counts:?}");
        assert!(
            counts[1] as f64 > total as f64 * 0.5,
            "shared bandit should concentrate on arm 1: {counts:?}"
        );
        let vals = ctrl.arm_values().unwrap();
        assert!(vals[1] > vals[0] && vals[1] > vals[2], "{vals:?}");
    }

    #[test]
    fn token_shared_ladder_accumulates_from_all_workers() {
        let ctrl = SharedController::new(&spec("token-ucb1"), 8);
        let sig = TokenSignals::from_logits(&[5.0, 0.0, 0.0, 0.0]);
        let positions = 4;
        let per_thread = 25;
        std::thread::scope(|scope| {
            for t in 0..2 {
                let mut session = ctrl.session().unwrap();
                let sig = sig;
                scope.spawn(move || {
                    let mut rng = Rng::new(7 + t as u64);
                    for _ in 0..per_thread {
                        session.session_start(&mut rng);
                        for i in 0..positions {
                            let _ = session.should_stop(&sig, i, &mut rng);
                        }
                        session.on_verify(2, positions);
                    }
                });
            }
        });
        let counts = ctrl.arm_counts().unwrap();
        // every (thread, session, position) triple played exactly one arm
        assert_eq!(counts.iter().sum::<u64>(), 2 * per_thread as u64 * positions as u64);
        assert!(ctrl.is_shared());
    }

    #[test]
    fn stateless_methods_get_private_controllers() {
        let ctrl = SharedController::new(&spec("static-3"), 128);
        assert!(!ctrl.is_shared());
        assert!(ctrl.arm_counts().is_none());
        assert!(ctrl.arm_values().is_none());
        let mut session = ctrl.session().unwrap();
        let mut rng = Rng::new(0);
        session.session_start(&mut rng);
        let sig = TokenSignals::from_logits(&[3.0, 0.0]);
        assert!(!session.should_stop(&sig, 0, &mut rng));
        assert!(!session.should_stop(&sig, 1, &mut rng));
        assert!(session.should_stop(&sig, 2, &mut rng));
        session.on_verify(2, 3);
        assert_eq!(ctrl.sessions(), 1);
        assert_eq!(ctrl.updates(), 1);
    }

    #[test]
    fn aborted_rounds_keep_counts_conserved() {
        // a round that errors after session_start but before on_verify is
        // absorbed as a zero-reward play (DecodeControl::on_abort) — the
        // conservation invariant sessions == updates == Σ counts survives
        let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
        let mut session = ctrl.session().unwrap();
        let mut rng = Rng::new(9);
        for i in 0..20 {
            session.session_start(&mut rng);
            if i % 3 == 0 {
                session.on_abort();
            } else {
                session.on_verify(3, 6);
            }
        }
        assert_eq!(ctrl.sessions(), 20);
        assert_eq!(ctrl.updates(), 20);
        assert_eq!(ctrl.arm_counts().unwrap().iter().sum::<u64>(), 20);

        // token granularity: every chosen position's play lands exactly once
        let ctrl = SharedController::new(&spec("token-ucb1"), 8);
        let mut session = ctrl.session().unwrap();
        let sig = TokenSignals::from_logits(&[5.0, 0.0, 0.0, 0.0]);
        let mut plays = 0u64;
        for i in 0..10 {
            session.session_start(&mut rng);
            for idx in 0..3 {
                let _ = session.should_stop(&sig, idx, &mut rng);
            }
            plays += 3;
            if i % 2 == 0 {
                session.on_abort();
            } else {
                session.on_verify(1, 3);
            }
        }
        assert_eq!(ctrl.arm_counts().unwrap().iter().sum::<u64>(), plays);
        assert_eq!(ctrl.sessions(), ctrl.updates());
    }

    #[test]
    fn keyed_sessions_conserve_the_global_ledger_and_diverge() {
        // two tenants whose rewarding arms differ: each keyed posterior
        // concentrates on its own arm while the global ledger still
        // absorbs every update (Σ global counts == updates == sessions)
        let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
        let mut session = ctrl.session().unwrap();
        let mut rng = Rng::new(11);
        let rounds = 400;
        for i in 0..rounds {
            let (tenant, good_arm) = if i % 2 == 0 { ("code", 1) } else { ("chat", 2) };
            session.set_context(tenant, 0);
            session.session_start(&mut rng);
            let (acc, dr) =
                if session.current_arm() == Some(good_arm) { (5, 6) } else { (1, 6) };
            if i % 17 == 0 {
                session.on_abort();
            } else {
                session.on_verify(acc, dr);
            }
        }
        assert_eq!(ctrl.sessions(), rounds);
        assert_eq!(ctrl.updates(), rounds);
        assert_eq!(
            ctrl.arm_counts().unwrap().iter().sum::<u64>(),
            rounds,
            "keyed updates still land in the global ledger"
        );
        let snap = ctrl.tenant_arm_snapshot();
        let keys: Vec<&str> = snap.iter().map(|(k, _, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["chat#0", "code#0"], "sorted keyed readout");
        let code = &snap[1];
        let chat = &snap[0];
        let modal = |c: &[u64]| c.iter().enumerate().max_by_key(|(_, &n)| n).unwrap().0;
        assert_eq!(modal(&code.1), 1, "code tenant concentrates on arm 1: {:?}", code.1);
        assert_eq!(modal(&chat.1), 2, "chat tenant concentrates on arm 2: {:?}", chat.1);
    }

    #[test]
    fn unseen_tenant_inherits_the_global_posterior() {
        // warm up the global tenant on arm 1, then a fresh tenant's very
        // first selection must already be arm 1 (hierarchical prior)
        let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
        let mut session = ctrl.session().unwrap();
        let mut rng = Rng::new(4);
        for _ in 0..300 {
            session.session_start(&mut rng);
            let (acc, dr) = if session.current_arm() == Some(1) { (6, 6) } else { (0, 6) };
            session.on_verify(acc, dr);
        }
        session.set_context("fresh-tenant", 0);
        session.session_start(&mut rng);
        assert_eq!(session.current_arm(), Some(1), "cold tenant starts at the global best");
        session.on_verify(6, 6);
    }

    #[test]
    fn default_context_is_the_legacy_global_path() {
        // set_context("", 0) must be indistinguishable from never calling
        // it: same bandit, same RNG draws, so default traffic replays
        // byte-identically to the pre-pool engine
        let run = |touch: bool| -> Vec<Option<usize>> {
            let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
            let mut session = ctrl.session().unwrap();
            let mut rng = Rng::new(21);
            (0..50)
                .map(|_| {
                    if touch {
                        session.set_context("", 0);
                    }
                    session.session_start(&mut rng);
                    let arm = session.current_arm();
                    session.on_verify(3, 6);
                    arm
                })
                .collect()
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn session_reset_preserves_shared_memory() {
        let ctrl = SharedController::new(&spec("seq-ucb1"), 128);
        let mut session = ctrl.session().unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            session.session_start(&mut rng);
            session.on_verify(3, 6);
        }
        session.reset_request();
        let counts = ctrl.arm_counts().unwrap();
        assert_eq!(counts.iter().sum::<u64>(), 10, "bandit memory survives reset_request");
    }
}
