//! TapOut controllers (paper §3.1): bind a bandit to the arm-policy pool at
//! either granularity.
//!
//! * `SeqBandit` — one arm is chosen at the start of each drafting session
//!   and drives every stop decision in it; rewarded with r_simple/r_blend.
//! * `TokenBandit` — every draft position is its own bandit; position i is
//!   rewarded 1 iff the token drafted at i was accepted.

use super::{make_bandit, BoxedBandit};
use crate::policies::BoxedPolicy;
use crate::signals::TokenSignals;
use crate::util::Rng;

/// Reward formulations (paper §3.2). `gamma` is the max draft length.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Reward {
    /// r_simple = |Y| / γ — normalized acceptance length.
    Simple,
    /// r_blend = α·|Y|/γ + (1-α)·|Y|/|X| (α = 0.5 in the paper).
    Blend(f64),
}

impl Reward {
    /// Reward for a session that had `accepted` of `drafted` proposals
    /// survive, under draft-length cap `gamma_max`.
    pub fn compute(&self, accepted: usize, drafted: usize, gamma_max: usize) -> f64 {
        let y = accepted as f64;
        let x = drafted.max(1) as f64;
        let g = gamma_max.max(1) as f64;
        match self {
            Reward::Simple => y / g,
            Reward::Blend(alpha) => alpha * y / g + (1.0 - alpha) * y / x,
        }
    }

    /// Paper-style label ("r_simple" / "r_blend").
    pub fn label(&self) -> &'static str {
        match self {
            Reward::Simple => "r_simple",
            Reward::Blend(_) => "r_blend",
        }
    }
}

/// Sequence-level TapOut controller.
pub struct SeqBandit {
    /// the learner over the arm pool
    pub bandit: BoxedBandit,
    /// stop-policy arm pool (paper Table 1 / App. A.2)
    pub arms: Vec<BoxedPolicy>,
    /// reward formulation fed to the learner
    pub reward: Reward,
    /// draft-length cap used to normalize rewards
    pub gamma_max: usize,
    current: usize,
    /// per-session snapshots of arm values (the Figs. 5-6 readout)
    pub value_history: Vec<Vec<f64>>,
    /// record `value_history` on every verify (off by default)
    pub track_history: bool,
}

impl SeqBandit {
    /// A sequence-level controller over `arms` driven by a fresh
    /// `bandit_kind` learner.
    pub fn new(
        bandit_kind: &str,
        arms: Vec<BoxedPolicy>,
        reward: Reward,
        gamma_max: usize,
    ) -> Self {
        let n = arms.len();
        SeqBandit {
            bandit: make_bandit(bandit_kind, n),
            arms,
            reward,
            gamma_max,
            current: 0,
            value_history: Vec::new(),
            track_history: false,
        }
    }

    /// Select the arm that will drive the coming drafting session.
    pub fn session_start(&mut self, rng: &mut Rng) {
        self.current = self.bandit.select(rng);
        self.arms[self.current].on_session_start();
    }

    /// Arm selected for the current session.
    pub fn current_arm(&self) -> usize {
        self.current
    }

    /// Delegate the stop decision to the session's arm.
    pub fn should_stop(&mut self, sig: &TokenSignals, idx: usize) -> bool {
        self.arms[self.current].should_stop(sig, idx)
    }

    /// Reward the session's arm with the verification outcome.
    pub fn on_verify(&mut self, accepted: usize, drafted: usize) {
        let r = self.reward.compute(accepted, drafted, self.gamma_max);
        self.bandit.update(self.current, r);
        // only the arm that drove the session sees the outcome — arms are
        // independent algorithms whose state reflects *their own* play
        self.arms[self.current].on_verify(accepted, drafted);
        if self.track_history {
            self.value_history.push(self.bandit.values());
        }
    }

    /// Names of the arms in play.
    pub fn arm_names(&self) -> Vec<String> {
        self.arms.iter().map(|a| a.name()).collect()
    }

    /// Start a new request stream.
    pub fn reset(&mut self) {
        // per-request policy state resets; bandit memory persists across
        // requests (the whole point of an *online* method)
        for a in &mut self.arms {
            a.reset();
        }
    }
}

/// Token-level TapOut controller: an independent bandit per draft position.
pub struct TokenBandit {
    kind: String,
    n_arms: usize,
    /// one lazily grown learner per draft position
    pub bandits: Vec<BoxedBandit>,
    /// stop-policy arm pool shared by every position
    pub arms: Vec<BoxedPolicy>,
    /// draft-length cap (ladder never grows past it)
    pub gamma_max: usize,
    chosen: Vec<usize>,
}

impl TokenBandit {
    /// A token-level controller over `arms` with an empty position ladder.
    pub fn new(bandit_kind: &str, arms: Vec<BoxedPolicy>, gamma_max: usize) -> Self {
        TokenBandit {
            kind: bandit_kind.to_string(),
            n_arms: arms.len(),
            bandits: Vec::new(),
            arms,
            gamma_max,
            chosen: Vec::new(),
        }
    }

    /// Begin a drafting session (clears the per-session arm choices).
    pub fn session_start(&mut self, _rng: &mut Rng) {
        self.chosen.clear();
        for a in &mut self.arms {
            a.on_session_start();
        }
    }

    fn bandit_at(&mut self, idx: usize) -> &mut BoxedBandit {
        while self.bandits.len() <= idx {
            self.bandits.push(make_bandit(&self.kind, self.n_arms));
        }
        &mut self.bandits[idx]
    }

    /// Select position `idx`'s arm and delegate the stop decision to it.
    pub fn should_stop(&mut self, sig: &TokenSignals, idx: usize, rng: &mut Rng) -> bool {
        let arm = self.bandit_at(idx).select(rng);
        debug_assert_eq!(self.chosen.len(), idx);
        self.chosen.push(arm);
        self.arms[arm].should_stop(sig, idx)
    }

    /// Reward each played position: 1 iff its token was accepted.
    pub fn on_verify(&mut self, accepted: usize, drafted: usize) {
        for i in 0..drafted.min(self.chosen.len()) {
            let r = if i < accepted { 1.0 } else { 0.0 };
            let arm = self.chosen[i];
            self.bandit_at(i).update(arm, r);
        }
        // stateful arms observe the session outcome once
        for a in &mut self.arms {
            a.on_verify(accepted, drafted);
        }
    }

    /// Start a new request stream (ladder memory persists).
    pub fn reset(&mut self) {
        for a in &mut self.arms {
            a.reset();
        }
        self.chosen.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::pool::default_arms;

    #[test]
    fn reward_formulas_match_paper() {
        // |Y| = 3, |X| = 4, γ = 128
        let r_simple = Reward::Simple.compute(3, 4, 128);
        assert!((r_simple - 3.0 / 128.0).abs() < 1e-12);
        let r_blend = Reward::Blend(0.5).compute(3, 4, 128);
        assert!((r_blend - (0.5 * 3.0 / 128.0 + 0.5 * 0.75)).abs() < 1e-12);
        // full rejection
        assert_eq!(Reward::Blend(0.5).compute(0, 6, 128), 0.0);
    }

    #[test]
    fn blend_rewards_acceptance_rate_not_just_length() {
        // 8 accepted of 32 drafted vs 4 accepted of 5 drafted
        let aggressive = Reward::Blend(0.5).compute(8, 32, 128);
        let conservative = Reward::Blend(0.5).compute(4, 5, 128);
        assert!(conservative > aggressive);
        // r_simple prefers the aggressive session
        assert!(Reward::Simple.compute(8, 32, 128) > Reward::Simple.compute(4, 5, 128));
    }

    #[test]
    fn seq_bandit_learns_to_prefer_rewarding_arm() {
        // Arms differ only in name; we reward arm 1 manually by hijacking
        // on_verify based on which arm is current.
        let mut c = SeqBandit::new("ucb1", default_arms(), Reward::Blend(0.5), 128);
        let mut rng = Rng::new(9);
        for _ in 0..400 {
            c.session_start(&mut rng);
            let (acc, dr) = if c.current_arm() == 1 { (5, 6) } else { (1, 6) };
            c.on_verify(acc, dr);
        }
        let counts = c.bandit.counts();
        let total: u64 = counts.iter().sum();
        assert!(counts[1] as f64 > total as f64 * 0.5, "{counts:?}");
        let vals = c.bandit.values();
        assert!(vals[1] > vals[0] && vals[1] > vals[2]);
    }

    #[test]
    fn seq_bandit_history_tracking() {
        let mut c = SeqBandit::new("ucb1", default_arms(), Reward::Blend(0.5), 128);
        c.track_history = true;
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            c.session_start(&mut rng);
            c.on_verify(3, 6);
        }
        assert_eq!(c.value_history.len(), 10);
        assert_eq!(c.value_history[0].len(), 5);
    }

    #[test]
    fn token_bandit_rewards_prefix_positions() {
        let mut c = TokenBandit::new("ts-beta", default_arms(), 8);
        let mut rng = Rng::new(4);
        let sig = TokenSignals::from_logits(&[5.0, 0.0, 0.0, 0.0]);
        for _ in 0..50 {
            c.session_start(&mut rng);
            for i in 0..4 {
                let _ = c.should_stop(&sig, i, &mut rng);
            }
            c.on_verify(2, 4); // positions 0,1 accepted; 2,3 rejected
        }
        let v_early = c.bandits[0].values();
        let v_late = c.bandits[3].values();
        let avg = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        assert!(avg(&v_early) > avg(&v_late));
    }

    #[test]
    fn token_bandit_grows_lazily() {
        let mut c = TokenBandit::new("ucb1", default_arms(), 128);
        let mut rng = Rng::new(2);
        c.session_start(&mut rng);
        let sig = TokenSignals::from_logits(&[1.0, 0.0]);
        for i in 0..7 {
            let _ = c.should_stop(&sig, i, &mut rng);
        }
        assert_eq!(c.bandits.len(), 7);
        c.on_verify(3, 7);
    }
}
