//! `StopController` — the single dispatch point the decoding session talks
//! to. Wraps the Static-γ baseline, a single stop policy (the tuned
//! baselines), or a TapOut bandit at either granularity.

use crate::bandit::{Reward, SeqBandit, TokenBandit};
use crate::policies::pool::{default_arms, multi_threshold_arms};
use crate::policies::{
    AdaEdl, AlwaysContinue, BoxedPolicy, LogitMargin, MaxConfidence, SpecDecPP, StaticLen,
    Svip, SvipDiff,
};
use crate::policies::StopPolicy;
use crate::signals::TokenSignals;
use crate::util::Rng;

/// The harness/CLI stop controller (one owner, one decode loop).
pub enum StopController {
    /// fixed-length drafting (the Static-γ baseline)
    Static(StaticLen),
    /// a single tuned stop policy
    Policy(BoxedPolicy),
    /// sequence-level TapOut bandit
    Seq(SeqBandit),
    /// token-level TapOut bandit ladder
    Token(TokenBandit),
}

/// What the decoding session (`spec::generate`) needs from a controller.
///
/// Two implementors exist: [`StopController`] (the single-threaded harness
/// and CLI path — one controller owned by one loop) and
/// `bandit::SessionController` (the serving path — per-worker session
/// state over a process-wide shared bandit; see DESIGN.md §2).
pub trait DecodeControl: Send {
    /// A new drafting session begins (bandit arm selection happens here).
    fn session_start(&mut self, rng: &mut Rng);

    /// Should drafting stop after the proposal at position `idx`?
    fn should_stop(&mut self, sig: &TokenSignals, idx: usize, rng: &mut Rng) -> bool;

    /// Verification outcome for the session: `accepted` of `drafted`.
    fn on_verify(&mut self, accepted: usize, drafted: usize);

    /// The session that [`DecodeControl::session_start`] opened will never
    /// see a verification outcome — a model error or a dropped batch seat
    /// killed the round before the target's rows came back. Implementations
    /// with play-count accounting must absorb the abort so counts stay
    /// conserved (the aborted round accepted nothing, so a zero-reward
    /// observation is the honest outcome). Default: no-op, for stateless
    /// controllers.
    fn on_abort(&mut self) {}

    /// A new request begins (per-request policy state resets; bandit
    /// memory persists — the whole point of an *online* method).
    fn reset_request(&mut self);

    /// Bind the controller to a request's (tenant, drafter) context
    /// (docs/ARCHITECTURE.md §17): tenant-keyed bandits route plays and
    /// rewards to the `"{tenant}#{drafter}"` posterior, so a code tenant
    /// and a chat tenant learn different stop policies per drafter.
    /// Default: no-op — single-owner controllers (harness/CLI) and the
    /// global tenant keep their exact pre-pool behavior.
    fn set_context(&mut self, _tenant: &str, _drafter: usize) {}

    /// Arm that drove the current session (Seq-granularity bandits only).
    fn current_arm(&self) -> Option<usize> {
        None
    }
}

impl DecodeControl for StopController {
    fn session_start(&mut self, rng: &mut Rng) {
        StopController::session_start(self, rng)
    }

    fn should_stop(&mut self, sig: &TokenSignals, idx: usize, rng: &mut Rng) -> bool {
        StopController::should_stop(self, sig, idx, rng)
    }

    fn on_verify(&mut self, accepted: usize, drafted: usize) {
        StopController::on_verify(self, accepted, drafted)
    }

    fn reset_request(&mut self) {
        StopController::reset_request(self)
    }

    fn current_arm(&self) -> Option<usize> {
        StopController::current_arm(self)
    }
}

/// Method specification as used by the CLI / experiment harness. Matches
/// the row labels of paper Tables 3-5.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodSpec {
    /// Static-k drafting (vanilla speculative decoding)
    Static(usize),
    /// AdaEDL with its adaptive λ threshold
    AdaEdl,
    /// SVIP at threshold h
    Svip(f32),
    /// Max-Confidence at threshold h
    MaxConf(f32),
    /// Logit-Margin at threshold h
    LogitMargin(f32),
    /// SVIP-Difference at threshold h
    SvipDiff(f32),
    /// SpecDec++ classifier (payload: path to specdecpp.json)
    SpecDecPP(String),
    /// sequence-level TapOut bandit over the arm pool
    SeqBandit {
        /// bandit kind ("ucb1" | "ucb-tuned" | "ts-gaussian")
        kind: String,
        /// reward formulation
        reward: Reward,
        /// use the 13-arm App. A.2 ablation pool
        multi_arms: bool,
    },
    /// token-level TapOut bandit ladder
    TokenBandit {
        /// bandit kind ("ucb1" | "ts-beta")
        kind: String,
        /// use the 13-arm App. A.2 ablation pool
        multi_arms: bool,
    },
}

impl MethodSpec {
    /// Parse CLI names: static-6, ada-edl, svip, max-conf, logit-margin,
    /// svip-diff, specdec++, seq-ucb1, seq-ucb-tuned, seq-ts, token-ucb1,
    /// token-ts (optionally ":rsimple" or ":multi" suffixes on bandits).
    pub fn parse(s: &str, artifacts_dir: &str) -> Result<MethodSpec, String> {
        let (base, opts) = match s.split_once(':') {
            Some((b, o)) => (b, o.split(',').collect::<Vec<_>>()),
            None => (s, vec![]),
        };
        let reward = if opts.contains(&"rsimple") {
            Reward::Simple
        } else {
            Reward::Blend(0.5)
        };
        let multi_arms = opts.contains(&"multi");
        let seq = |kind: &str| MethodSpec::SeqBandit {
            kind: kind.into(),
            reward,
            multi_arms,
        };
        let tok = |kind: &str| MethodSpec::TokenBandit { kind: kind.into(), multi_arms };
        Ok(match base {
            _ if base.starts_with("static-") => {
                let k = base[7..].parse().map_err(|_| format!("bad static k in {s}"))?;
                MethodSpec::Static(k)
            }
            "ada-edl" => MethodSpec::AdaEdl,
            "svip" => MethodSpec::Svip(0.6),
            "max-conf" => MethodSpec::MaxConf(0.8),
            "logit-margin" => MethodSpec::LogitMargin(0.2),
            "svip-diff" => MethodSpec::SvipDiff(0.2),
            "specdec++" => {
                MethodSpec::SpecDecPP(format!("{artifacts_dir}/specdecpp.json"))
            }
            "seq-ucb1" => seq("ucb1"),
            "seq-ucb-tuned" => seq("ucb-tuned"),
            "seq-ts" => seq("ts-gaussian"),
            "token-ucb1" => tok("ucb1"),
            "token-ts" => tok("ts-beta"),
            other => return Err(format!("unknown method: {other}")),
        })
    }

    /// Paper-style row label (Tables 3-5).
    pub fn label(&self) -> String {
        match self {
            MethodSpec::Static(k) => format!("Static-{k}"),
            MethodSpec::AdaEdl => "AdaEDL".into(),
            MethodSpec::Svip(_) => "SVIP".into(),
            MethodSpec::MaxConf(_) => "MC".into(),
            MethodSpec::LogitMargin(_) => "LogitMargin".into(),
            MethodSpec::SvipDiff(_) => "SVIPDiff".into(),
            MethodSpec::SpecDecPP(_) => "SpecDec++".into(),
            MethodSpec::SeqBandit { kind, reward, multi_arms } => {
                let mut s = format!("TapOut-Seq-{}", pretty_kind(kind));
                if *reward == Reward::Simple {
                    s.push_str("(r_simple)");
                }
                if *multi_arms {
                    s.push_str("(multi)");
                }
                s
            }
            MethodSpec::TokenBandit { kind, .. } => {
                format!("TapOut-Token-{}", pretty_kind(kind))
            }
        }
    }

    /// Does this method require hyperparameter tuning? (paper column)
    pub fn tuning_required(&self) -> bool {
        matches!(
            self,
            MethodSpec::AdaEdl
                | MethodSpec::Svip(_)
                | MethodSpec::MaxConf(_)
                | MethodSpec::LogitMargin(_)
                | MethodSpec::SvipDiff(_)
                | MethodSpec::SpecDecPP(_)
        )
    }

    /// Materialize the controller this spec describes.
    pub fn build(&self, gamma_max: usize) -> anyhow::Result<StopController> {
        Ok(match self {
            MethodSpec::Static(k) => StopController::Static(StaticLen::new(*k)),
            MethodSpec::AdaEdl => StopController::Policy(Box::new(AdaEdl::default())),
            MethodSpec::Svip(h) => StopController::Policy(Box::new(Svip::new(*h))),
            MethodSpec::MaxConf(h) => {
                StopController::Policy(Box::new(MaxConfidence::new(*h)))
            }
            MethodSpec::LogitMargin(h) => {
                StopController::Policy(Box::new(LogitMargin::new(*h)))
            }
            MethodSpec::SvipDiff(h) => {
                StopController::Policy(Box::new(SvipDiff::new(*h)))
            }
            MethodSpec::SpecDecPP(path) => StopController::Policy(Box::new(
                SpecDecPP::load(std::path::Path::new(path))
                    .map_err(|e| anyhow::anyhow!("specdec++ load: {e}"))?,
            )),
            MethodSpec::SeqBandit { kind, reward, multi_arms } => {
                let arms = if *multi_arms { multi_threshold_arms() } else { default_arms() };
                StopController::Seq(SeqBandit::new(kind, arms, *reward, gamma_max))
            }
            MethodSpec::TokenBandit { kind, multi_arms } => {
                let arms = if *multi_arms { multi_threshold_arms() } else { default_arms() };
                StopController::Token(TokenBandit::new(kind, arms, gamma_max))
            }
        })
    }

    /// The method names every paper table sweeps.
    pub fn all_paper_methods() -> Vec<&'static str> {
        vec![
            "static-6", "ada-edl", "svip", "max-conf", "seq-ts", "seq-ucb1",
            "token-ts", "token-ucb1",
        ]
    }
}

fn pretty_kind(kind: &str) -> &'static str {
    match kind {
        "ucb1" => "UCB1",
        "ucb-tuned" => "UCBTuned",
        "ts-gaussian" | "ts-beta" => "TS",
        _ => "?",
    }
}

impl StopController {
    /// A probe controller that never stops early (trace collection).
    pub fn always_continue() -> StopController {
        StopController::Policy(Box::new(AlwaysContinue))
    }

    /// A new drafting session begins (bandit arm selection).
    pub fn session_start(&mut self, rng: &mut Rng) {
        match self {
            StopController::Static(_) => {}
            StopController::Policy(p) => p.on_session_start(),
            StopController::Seq(c) => c.session_start(rng),
            StopController::Token(c) => c.session_start(rng),
        }
    }

    /// Stop drafting after the proposal at `idx`?
    pub fn should_stop(&mut self, sig: &TokenSignals, idx: usize, rng: &mut Rng) -> bool {
        match self {
            StopController::Static(p) => p.should_stop(sig, idx),
            StopController::Policy(p) => p.should_stop(sig, idx),
            StopController::Seq(c) => c.should_stop(sig, idx),
            StopController::Token(c) => c.should_stop(sig, idx, rng),
        }
    }

    /// Deliver a session's verification outcome.
    pub fn on_verify(&mut self, accepted: usize, drafted: usize) {
        match self {
            StopController::Static(_) => {}
            StopController::Policy(p) => p.on_verify(accepted, drafted),
            StopController::Seq(c) => c.on_verify(accepted, drafted),
            StopController::Token(c) => c.on_verify(accepted, drafted),
        }
    }

    /// A new request begins (per-request state resets; learning persists).
    pub fn reset_request(&mut self) {
        match self {
            StopController::Static(_) => {}
            StopController::Policy(p) => p.reset(),
            StopController::Seq(c) => c.reset(),
            StopController::Token(c) => c.reset(),
        }
    }

    /// Arm-value readout for interpretability experiments (Seq only).
    pub fn arm_values(&self) -> Option<Vec<f64>> {
        match self {
            StopController::Seq(c) => Some(c.bandit.values()),
            _ => None,
        }
    }

    /// Arm driving the current session (Seq granularity only).
    pub fn current_arm(&self) -> Option<usize> {
        match self {
            StopController::Seq(c) => Some(c.current_arm()),
            _ => None,
        }
    }

    /// Toggle per-session arm-value snapshots (Figs. 5-6).
    pub fn set_track_history(&mut self, on: bool) {
        if let StopController::Seq(c) = self {
            c.track_history = on;
        }
    }

    /// Recorded arm-value snapshots, if tracking was on (Seq only).
    pub fn value_history(&self) -> Option<&[Vec<f64>]> {
        match self {
            StopController::Seq(c) => Some(&c.value_history),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_paper_methods() {
        for name in MethodSpec::all_paper_methods() {
            let m = MethodSpec::parse(name, "artifacts").unwrap();
            assert!(!m.label().is_empty());
        }
        assert!(MethodSpec::parse("nope", ".").is_err());
    }

    #[test]
    fn parse_options() {
        let m = MethodSpec::parse("seq-ucb1:rsimple", ".").unwrap();
        match m {
            MethodSpec::SeqBandit { reward, .. } => assert_eq!(reward, Reward::Simple),
            _ => panic!(),
        }
        let m = MethodSpec::parse("seq-ucb1:multi", ".").unwrap();
        match m {
            MethodSpec::SeqBandit { multi_arms, .. } => assert!(multi_arms),
            _ => panic!(),
        }
        assert_eq!(
            MethodSpec::parse("static-8", ".").unwrap(),
            MethodSpec::Static(8)
        );
    }

    #[test]
    fn tuning_column_matches_paper() {
        assert!(!MethodSpec::parse("static-6", ".").unwrap().tuning_required());
        assert!(MethodSpec::parse("svip", ".").unwrap().tuning_required());
        assert!(MethodSpec::parse("ada-edl", ".").unwrap().tuning_required());
        assert!(!MethodSpec::parse("seq-ucb1", ".").unwrap().tuning_required());
        assert!(!MethodSpec::parse("token-ts", ".").unwrap().tuning_required());
    }

    #[test]
    fn build_and_drive_static() {
        let mut c = MethodSpec::Static(3).build(128).unwrap();
        let mut rng = Rng::new(0);
        c.session_start(&mut rng);
        let sig = TokenSignals::from_logits(&[3.0, 0.0]);
        assert!(!c.should_stop(&sig, 0, &mut rng));
        assert!(!c.should_stop(&sig, 1, &mut rng));
        assert!(c.should_stop(&sig, 2, &mut rng));
        c.on_verify(2, 3);
    }
}
