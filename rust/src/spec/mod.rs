//! Speculative decoding core: Algorithm 1 session loop + the stop
//! controller that hosts the paper's methods.

pub mod session;
pub mod stop;

pub use session::{
    accept_greedy, finish_check, generate, greedy, validate_prompt, FinishReason, GenConfig,
    GenResult, RoundStat, SpecSession, StepCommit, StepOutcome, BOS, EOS,
};
pub use stop::{DecodeControl, MethodSpec, StopController};
