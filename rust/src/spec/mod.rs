//! Speculative decoding core: Algorithm 1 session loop + the stop
//! controller that hosts the paper's methods.

pub mod session;
pub mod stop;

pub use session::{
    generate, greedy, FinishReason, GenConfig, GenResult, RoundStat, SpecSession, StepCommit,
    StepOutcome, BOS, EOS,
};
pub use stop::{DecodeControl, MethodSpec, StopController};
