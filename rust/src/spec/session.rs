//! The speculative-decoding session loop — Algorithm 1 of the paper, with
//! greedy (exact-match) verification and the contiguous-cursor KV protocol
//! described in models/traits.rs and DESIGN.md §4.
//!
//! The loop is written against [`DecodeControl`], so the same code path
//! serves both the single-threaded harness (`StopController`) and the
//! multi-worker engine (`bandit::SessionController` over a shared bandit,
//! DESIGN.md §2).
//!
//! The *target* side is equally polymorphic: in the batched serving
//! engine, `target` is an `engine::BatchedTarget` handle, so the single
//! verification `block` per round becomes a submit/await against the
//! cross-session batcher (docs/ARCHITECTURE.md §4) — the loop itself is
//! byte-identical either way, which is what keeps batched and sequential
//! outputs equal.

use std::time::Instant;

use crate::bandit::DrafterHook;
use crate::models::traits::LanguageModel;
use crate::signals::TokenSignals;
use crate::util::Rng;

use super::stop::DecodeControl;

/// End-of-sequence token id (shared by the sim and artifact tokenizers).
pub const EOS: u32 = 2;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 1;

/// Generation limits and switches for one request.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// maximum tokens to generate past the prompt
    pub max_new: usize,
    /// max draft length γ (128 in the paper's dynamic setting)
    pub gamma_max: usize,
    /// stop at EOS (disable for fixed-length benchmarking)
    pub stop_at_eos: bool,
    /// keep per-token signal rows in the round stats (Fig. 2 / classifier)
    pub collect_signals: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_new: 160, gamma_max: 128, stop_at_eos: true, collect_signals: false }
    }
}

/// Outcome of one draft/verify round.
#[derive(Clone, Debug, Default)]
pub struct RoundStat {
    /// proposals drafted this round
    pub drafted: usize,
    /// proposals the target accepted (the bonus token is extra)
    pub accepted: usize,
    /// bandit arm that drove this session (Seq controllers only)
    pub arm: Option<usize>,
    /// wall time of the draft phase
    pub draft_ns: u64,
    /// wall time of the verification phase (includes batcher queueing in
    /// the batched engine)
    pub verify_ns: u64,
    /// per-proposal signal rows (kept only when `collect_signals` is on)
    pub signals: Vec<TokenSignals>,
}

/// One finished generation: the committed sequence plus round stats.
#[derive(Clone, Debug, Default)]
pub struct GenResult {
    /// full committed sequence (prompt + generation)
    pub tokens: Vec<u32>,
    /// length of the prompt prefix inside `tokens`
    pub prompt_len: usize,
    /// one entry per draft/verify round
    pub rounds: Vec<RoundStat>,
    /// decode wall time
    pub wall_ns: u64,
    /// prompt positions whose prefill was skipped via cross-request
    /// prefix reuse (docs/ARCHITECTURE.md §12); 0 for a fresh decode.
    /// Purely an accounting field: cached prefill never enters round
    /// stats, acceptance rates, or bandit rewards — those only ever
    /// describe drafted/verified positions, which a cache hit leaves
    /// untouched.
    pub cached_prefix: usize,
}

impl GenResult {
    /// The generated suffix (everything past the prompt).
    pub fn new_tokens(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }

    /// Total proposals drafted across all rounds.
    pub fn drafted(&self) -> usize {
        self.rounds.iter().map(|r| r.drafted).sum()
    }

    /// Total proposals accepted across all rounds.
    pub fn accepted(&self) -> usize {
        self.rounds.iter().map(|r| r.accepted).sum()
    }

    /// mean accepted length per drafting session (paper's m)
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.accepted() as f64 / self.rounds.len() as f64
    }

    /// acceptance rate (paper's %)
    pub fn acceptance_rate(&self) -> f64 {
        let d = self.drafted();
        if d == 0 {
            return 0.0;
        }
        self.accepted() as f64 / d as f64
    }
}

/// Why a step-driven decode reached its natural end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// the `max_new` generation budget is exhausted
    MaxNew,
    /// the last committed token is EOS (with `stop_at_eos` on)
    Eos,
    /// no KV headroom remains for another round
    KvExhausted,
}

/// Newly committed tokens plus bandit accounting from one
/// draft→verify→accept round ([`SpecSession::step`]).
#[derive(Clone, Debug)]
pub struct StepCommit {
    /// tokens committed by this round: accepted proposals + bonus token
    pub new_tokens: Vec<u32>,
    /// proposals drafted this round
    pub drafted: usize,
    /// proposals the target accepted
    pub accepted: usize,
    /// bandit arm that drove the session (Seq controllers only)
    pub arm: Option<usize>,
}

/// Result of one [`SpecSession::step`] call.
#[derive(Clone, Debug)]
pub enum StepOutcome {
    /// one round ran and committed at least one token (the bonus)
    Round(StepCommit),
    /// the decode is complete; this call committed nothing
    Finished(FinishReason),
}

/// Validate a prompt against the KV-cache geometry. Shared by
/// [`SpecSession::new`] and the continuous engine's admission
/// (`engine/stepper.rs`), so a rejected prompt fails with the identical
/// message in both execution modes.
pub fn validate_prompt(prompt: &[u32], max_seq: usize) -> anyhow::Result<()> {
    anyhow::ensure!(!prompt.is_empty(), "prompt must be non-empty");
    anyhow::ensure!(
        prompt.len() + 2 < max_seq,
        "prompt too long for KV cache: {} + 2 >= {max_seq}",
        prompt.len()
    );
    Ok(())
}

/// Termination check for a step-driven decode, in the same priority
/// order the classic `generate` loop used: budget, then EOS, then KV
/// headroom. Shared by [`SpecSession::step`]'s boundary check and the
/// engine's continuous stepper (`engine/stepper.rs`), so both decode
/// drivers stop at exactly the same boundary.
pub fn finish_check(
    committed_len: usize,
    prompt_len: usize,
    last: Option<u32>,
    cfg: &GenConfig,
    max_seq: usize,
) -> Option<FinishReason> {
    if committed_len - prompt_len >= cfg.max_new {
        return Some(FinishReason::MaxNew);
    }
    if cfg.stop_at_eos && last == Some(EOS) {
        return Some(FinishReason::Eos);
    }
    if max_seq.saturating_sub(committed_len + 2) < 1 {
        return Some(FinishReason::KvExhausted);
    }
    None
}

/// The greedy-verification accept rule (Algorithm 1's exact-match test),
/// shared by [`SpecSession::step`] and the engine's continuous stepper.
///
/// `vsig` are the target's signal rows for one verification block fed at
/// absolute position `tc` (committed catch-up + all proposals), `c` is
/// the committed length at round start, and `proposals` the drafted
/// tokens. Row `off + i` (with `off = c - 1 - tc`) predicts position
/// `c + i`, so it both checks `proposals[i]` and supplies the bonus
/// token. Returns `(accepted, bonus)`.
pub fn accept_greedy(
    vsig: &[TokenSignals],
    tc: usize,
    c: usize,
    proposals: &[u32],
) -> (usize, u32) {
    let off = c - 1 - tc;
    let mut m = 0;
    while m < proposals.len() && vsig[off + m].argmax == proposals[m] {
        m += 1;
    }
    (m, vsig[off + m].argmax)
}

/// A resumable speculative-decoding session: one draft→verify→accept
/// round per [`SpecSession::step`] call.
///
/// This is the step-driven core the serving engine builds its request
/// lifecycle on (docs/ARCHITECTURE.md §10): the caller owns the loop, so
/// it can check cancellation flags and deadlines, stream the committed
/// tokens, or interleave sessions — all at round granularity, which is
/// exactly the granularity at which TapOut's bandit reward lands.
/// [`generate`] is the thin run-to-completion loop over this type, so the
/// harness path and the engine path decode byte-identically.
pub struct SpecSession<'a> {
    draft: &'a mut dyn LanguageModel,
    target: &'a mut dyn LanguageModel,
    ctrl: &'a mut dyn DecodeControl,
    rng: &'a mut Rng,
    cfg: GenConfig,
    max_seq: usize,
    committed: Vec<u32>,
    prompt_len: usize,
    rounds: Vec<RoundStat>,
    t_start: Instant,
    finished: Option<FinishReason>,
    /// prompt positions covered by retained (cache-hit) sequence state
    cached_prefix: usize,
    /// drafter-selection hook (docs/ARCHITECTURE.md §17): when set, every
    /// round selects a pooled drafter before `session_start` and settles
    /// the drafter layer exactly once after verify or abort — the same
    /// per-round ledger discipline as the policy bandit, one layer up.
    /// `None` (harness/CLI) keeps the pre-pool behavior exactly.
    hook: Option<DrafterHook>,
}

impl<'a> SpecSession<'a> {
    /// Validate the prompt, reset both models and the controller, and
    /// return a session positioned before its first round.
    ///
    /// Invariants maintained across steps (tested in rust/tests/):
    ///   * both models only ever receive contiguous blocks starting at
    ///     their cursor;
    ///   * after every round both cursors ≤ committed length;
    ///   * committed tokens never change once appended (greedy spec
    ///     decoding is lossless: output == target-only greedy output).
    pub fn new(
        draft: &'a mut dyn LanguageModel,
        target: &'a mut dyn LanguageModel,
        ctrl: &'a mut dyn DecodeControl,
        rng: &'a mut Rng,
        prompt: &[u32],
        cfg: &GenConfig,
    ) -> anyhow::Result<SpecSession<'a>> {
        draft.reset();
        target.reset();
        SpecSession::resume(draft, target, ctrl, rng, prompt, cfg, 0)
    }

    /// Like [`SpecSession::new`], but *resume* over models whose first
    /// `resident` positions of sequence state are already valid for this
    /// prompt — the cross-request prefix-reuse entry point
    /// (docs/ARCHITECTURE.md §12). The models are **not** reset: both
    /// cursors are rolled back to `resident` and the first round's
    /// catch-up blocks prefill only `prompt[resident..]`.
    ///
    /// Guards (reuse is deliberate, never accidental):
    ///   * `resident < prompt.len()` — the last prompt token is always
    ///     re-fed, because its signal row seeds the first proposal and
    ///     the first verification block;
    ///   * after rollback, both cursors must sit exactly at `resident` —
    ///     a model that cannot cover the claimed prefix (e.g. a fresh
    ///     instance handed a stale reuse length) is an error here, not a
    ///     silently wrong decode.
    ///
    /// Round structure, acceptance stats, and bandit accounting are
    /// byte-identical to a fresh session: a cache hit only removes
    /// redundant prefill rows, which no consumer reads. `resident == 0`
    /// (with cursors at 0) is exactly a fresh session.
    pub fn resume(
        draft: &'a mut dyn LanguageModel,
        target: &'a mut dyn LanguageModel,
        ctrl: &'a mut dyn DecodeControl,
        rng: &'a mut Rng,
        prompt: &[u32],
        cfg: &GenConfig,
        resident: usize,
    ) -> anyhow::Result<SpecSession<'a>> {
        let t_start = Instant::now();
        let max_seq = draft.max_seq().min(target.max_seq());
        validate_prompt(prompt, max_seq)?;
        anyhow::ensure!(
            resident < prompt.len(),
            "resident prefix {resident} must leave ≥1 prompt token to feed ({})",
            prompt.len()
        );
        draft.rollback(resident);
        target.rollback(resident);
        anyhow::ensure!(
            draft.cur() == resident && target.cur() == resident,
            "resident-prefix contract violated: draft cursor {} / target cursor {} \
             cannot cover the claimed {resident} cached positions",
            draft.cur(),
            target.cur()
        );
        ctrl.reset_request();
        Ok(SpecSession {
            draft,
            target,
            ctrl,
            rng,
            cfg: *cfg,
            max_seq,
            prompt_len: prompt.len(),
            committed: prompt.to_vec(),
            rounds: Vec::new(),
            t_start,
            finished: None,
            cached_prefix: resident,
            hook: None,
        })
    }

    /// Attach the drafter-selection hook (serving engine only). With a
    /// pool of one the hook selects drafter 0 without drawing RNG, so
    /// attaching it never changes emitted tokens.
    pub fn set_drafter_hook(&mut self, hook: DrafterHook) {
        self.hook = Some(hook);
    }

    /// The full committed sequence so far (prompt + generation).
    pub fn committed(&self) -> &[u32] {
        &self.committed
    }

    /// Tokens generated past the prompt so far.
    pub fn generated(&self) -> usize {
        self.committed.len() - self.prompt_len
    }

    /// Rounds run so far.
    pub fn rounds(&self) -> &[RoundStat] {
        &self.rounds
    }

    /// Has the session reached its natural end?
    pub fn is_finished(&self) -> bool {
        self.finished.is_some()
    }

    /// Termination check at the step boundary ([`finish_check`]).
    fn check_done(&self) -> Option<FinishReason> {
        finish_check(
            self.committed.len(),
            self.prompt_len,
            self.committed.last().copied(),
            &self.cfg,
            self.max_seq,
        )
    }

    /// Run one draft→verify→accept round, or report that the decode is
    /// complete. A finished session keeps returning
    /// [`StepOutcome::Finished`]; an errored step leaves the committed
    /// prefix intact (verification is atomic — a round either commits
    /// fully or not at all).
    pub fn step(&mut self) -> anyhow::Result<StepOutcome> {
        if let Some(r) = self.finished {
            return Ok(StepOutcome::Finished(r));
        }
        if let Some(r) = self.check_done() {
            self.finished = Some(r);
            return Ok(StepOutcome::Finished(r));
        }

        let c = self.committed.len();
        let headroom = self.max_seq.saturating_sub(c + 2);
        let gamma = self.cfg.gamma_max.min(headroom);

        // drafter layer first (docs §17): pick which pooled drafter
        // proposes this round and bind the policy bandit to the request's
        // (tenant, drafter) context before its own arm selection
        if let Some(h) = self.hook.as_mut() {
            let d = h.begin_round();
            self.draft.set_drafter(d);
            self.ctrl.set_context(h.tenant(), d);
        }

        self.ctrl.session_start(self.rng);

        // the fallible middle of the round: a model error here means the
        // play opened by session_start never sees a verification outcome —
        // route it through on_abort so bandit counts stay conserved
        // (rust/tests/engine_faults.rs pins this under fault injection);
        // the drafter layer settles its play the same way, one layer up
        let (proposals, sig_rows, vsig, tc, draft_ns, verify_ns) =
            match self.draft_and_verify(c, gamma) {
                Ok(x) => x,
                Err(e) => {
                    self.ctrl.on_abort();
                    if let Some(h) = &self.hook {
                        h.settle_abort();
                    }
                    return Err(e);
                }
            };
        let (m, bonus) = accept_greedy(&vsig, tc, c, &proposals);

        self.committed.extend_from_slice(&proposals[..m]);
        self.committed.push(bonus);
        self.target.rollback(c + m);
        self.draft.rollback(c + m);

        self.ctrl.on_verify(m, proposals.len());
        // full-information drafter reward (Not-a-Bandit): score every
        // pooled drafter against the tokens this round actually committed.
        // Pure bookkeeping over known rows — emitted tokens are already
        // fixed above, so the sweep can never alter them.
        if let Some(h) = self.hook.as_mut() {
            let scores = self.draft.score_drafters(h.seed(), h.category(), &self.committed[c..], c);
            h.settle_verify(&scores);
        }
        let arm = self.ctrl.current_arm();
        self.rounds.push(RoundStat {
            drafted: proposals.len(),
            accepted: m,
            arm,
            draft_ns,
            verify_ns,
            signals: if self.cfg.collect_signals { sig_rows } else { Vec::new() },
        });

        // an EOS bonus is picked up by check_done on the next call — same
        // endpoint as the classic loop's eager break, one state fewer
        Ok(StepOutcome::Round(StepCommit {
            new_tokens: self.committed[c..].to_vec(),
            drafted: proposals.len(),
            accepted: m,
            arm,
        }))
    }

    /// The fallible middle of a round: the draft's catch-up block plus
    /// stop-ruled proposal blocks, then the target's single verification
    /// block. Split out of [`SpecSession::step`] so an error between
    /// `session_start` and `on_verify` can be absorbed via
    /// [`DecodeControl::on_abort`] (play-count conservation). Returns
    /// `(proposals, signal rows, verify rows, target cursor, draft ns,
    /// verify ns)`.
    #[allow(clippy::type_complexity)]
    fn draft_and_verify(
        &mut self,
        c: usize,
        gamma: usize,
    ) -> anyhow::Result<(Vec<u32>, Vec<TokenSignals>, Vec<TokenSignals>, usize, u64, u64)> {
        // --- draft session: catch up on committed suffix, then propose
        let t_draft = Instant::now();
        let dc = self.draft.cur();
        let mut sig = self.draft.block(&self.committed[dc..], dc)?;
        let mut proposals: Vec<u32> = Vec::with_capacity(gamma);
        let mut sig_rows: Vec<TokenSignals> = Vec::new();
        loop {
            let last = *sig.last().expect("block returns >=1 row");
            proposals.push(last.argmax);
            sig_rows.push(last);
            let idx = proposals.len() - 1;
            if proposals.len() >= gamma || self.ctrl.should_stop(&last, idx, self.rng) {
                break;
            }
            sig = self.draft.block(&[last.argmax], c + proposals.len() - 1)?;
        }
        let draft_ns = t_draft.elapsed().as_nanos() as u64;

        // --- verification: one parallel target block over the unprocessed
        // committed suffix + all proposals. Row off+i predicts position
        // c+i, so it both checks proposals[i] and supplies the bonus token.
        let t_verify = Instant::now();
        let tc = self.target.cur();
        let mut inputs: Vec<u32> = self.committed[tc..].to_vec();
        inputs.extend_from_slice(&proposals);
        let vsig = self.target.block(&inputs, tc)?;
        let verify_ns = t_verify.elapsed().as_nanos() as u64;
        Ok((proposals, sig_rows, vsig, tc, draft_ns, verify_ns))
    }

    /// Close the session and return the accumulated result. Valid at any
    /// step boundary — an early finish (cancellation, deadline) simply
    /// returns the committed prefix.
    pub fn finish(self) -> GenResult {
        // note: the final round may overshoot max_new; full rounds are
        // kept (matches the python reference decoder — verification is
        // atomic)
        GenResult {
            tokens: self.committed,
            prompt_len: self.prompt_len,
            rounds: self.rounds,
            wall_ns: self.t_start.elapsed().as_nanos() as u64,
            cached_prefix: self.cached_prefix,
        }
    }
}

/// Run one full generation with speculative decoding: the thin
/// run-to-completion loop over [`SpecSession`] (the harness / CLI path).
pub fn generate(
    draft: &mut dyn LanguageModel,
    target: &mut dyn LanguageModel,
    ctrl: &mut dyn DecodeControl,
    rng: &mut Rng,
    prompt: &[u32],
    cfg: &GenConfig,
) -> anyhow::Result<GenResult> {
    let mut session = SpecSession::new(draft, target, ctrl, rng, prompt, cfg)?;
    while let StepOutcome::Round(_) = session.step()? {}
    Ok(session.finish())
}

/// Plain target-only greedy decoding (the correctness oracle and the
/// "no speculation" latency reference).
pub fn greedy(
    target: &mut dyn LanguageModel,
    prompt: &[u32],
    cfg: &GenConfig,
) -> anyhow::Result<GenResult> {
    let t_start = Instant::now();
    target.reset();
    let mut committed = prompt.to_vec();
    let n0 = prompt.len();
    let max_seq = target.max_seq();
    while committed.len() - n0 < cfg.max_new && committed.len() + 1 < max_seq {
        let sig = target.block(&committed[target.cur()..], target.cur())?;
        let nxt = sig.last().unwrap().argmax;
        committed.push(nxt);
        if cfg.stop_at_eos && nxt == EOS {
            break;
        }
    }
    Ok(GenResult {
        tokens: committed,
        prompt_len: n0,
        rounds: vec![],
        wall_ns: t_start.elapsed().as_nanos() as u64,
        cached_prefix: 0,
    })
}
