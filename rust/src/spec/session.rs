//! The speculative-decoding session loop — Algorithm 1 of the paper, with
//! greedy (exact-match) verification and the contiguous-cursor KV protocol
//! described in models/traits.rs and DESIGN.md §4.
//!
//! The loop is written against [`DecodeControl`], so the same code path
//! serves both the single-threaded harness (`StopController`) and the
//! multi-worker engine (`bandit::SessionController` over a shared bandit,
//! DESIGN.md §2).
//!
//! The *target* side is equally polymorphic: in the batched serving
//! engine, `target` is an `engine::BatchedTarget` handle, so the single
//! verification `block` per round becomes a submit/await against the
//! cross-session batcher (docs/ARCHITECTURE.md §4) — the loop itself is
//! byte-identical either way, which is what keeps batched and sequential
//! outputs equal.

use std::time::Instant;

use crate::models::traits::LanguageModel;
use crate::signals::TokenSignals;
use crate::util::Rng;

use super::stop::DecodeControl;

/// End-of-sequence token id (shared by the sim and artifact tokenizers).
pub const EOS: u32 = 2;
/// Beginning-of-sequence token id.
pub const BOS: u32 = 1;

/// Generation limits and switches for one request.
#[derive(Clone, Copy, Debug)]
pub struct GenConfig {
    /// maximum tokens to generate past the prompt
    pub max_new: usize,
    /// max draft length γ (128 in the paper's dynamic setting)
    pub gamma_max: usize,
    /// stop at EOS (disable for fixed-length benchmarking)
    pub stop_at_eos: bool,
    /// keep per-token signal rows in the round stats (Fig. 2 / classifier)
    pub collect_signals: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { max_new: 160, gamma_max: 128, stop_at_eos: true, collect_signals: false }
    }
}

/// Outcome of one draft/verify round.
#[derive(Clone, Debug, Default)]
pub struct RoundStat {
    /// proposals drafted this round
    pub drafted: usize,
    /// proposals the target accepted (the bonus token is extra)
    pub accepted: usize,
    /// bandit arm that drove this session (Seq controllers only)
    pub arm: Option<usize>,
    /// wall time of the draft phase
    pub draft_ns: u64,
    /// wall time of the verification phase (includes batcher queueing in
    /// the batched engine)
    pub verify_ns: u64,
    /// per-proposal signal rows (kept only when `collect_signals` is on)
    pub signals: Vec<TokenSignals>,
}

/// One finished generation: the committed sequence plus round stats.
#[derive(Clone, Debug, Default)]
pub struct GenResult {
    /// full committed sequence (prompt + generation)
    pub tokens: Vec<u32>,
    /// length of the prompt prefix inside `tokens`
    pub prompt_len: usize,
    /// one entry per draft/verify round
    pub rounds: Vec<RoundStat>,
    /// decode wall time
    pub wall_ns: u64,
}

impl GenResult {
    /// The generated suffix (everything past the prompt).
    pub fn new_tokens(&self) -> &[u32] {
        &self.tokens[self.prompt_len..]
    }

    /// Total proposals drafted across all rounds.
    pub fn drafted(&self) -> usize {
        self.rounds.iter().map(|r| r.drafted).sum()
    }

    /// Total proposals accepted across all rounds.
    pub fn accepted(&self) -> usize {
        self.rounds.iter().map(|r| r.accepted).sum()
    }

    /// mean accepted length per drafting session (paper's m)
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.accepted() as f64 / self.rounds.len() as f64
    }

    /// acceptance rate (paper's %)
    pub fn acceptance_rate(&self) -> f64 {
        let d = self.drafted();
        if d == 0 {
            return 0.0;
        }
        self.accepted() as f64 / d as f64
    }
}

/// Run one full generation with speculative decoding.
///
/// Invariants maintained (tested in rust/tests/):
///   * both models only ever receive contiguous blocks starting at their
///     cursor;
///   * after every round both cursors ≤ committed length;
///   * committed tokens never change once appended (greedy spec decoding
///     is lossless: output == target-only greedy output).
pub fn generate(
    draft: &mut dyn LanguageModel,
    target: &mut dyn LanguageModel,
    ctrl: &mut dyn DecodeControl,
    rng: &mut Rng,
    prompt: &[u32],
    cfg: &GenConfig,
) -> anyhow::Result<GenResult> {
    let t_start = Instant::now();
    anyhow::ensure!(!prompt.is_empty(), "prompt must be non-empty");
    let max_seq = draft.max_seq().min(target.max_seq());
    anyhow::ensure!(
        prompt.len() + 2 < max_seq,
        "prompt too long for KV cache: {} + 2 >= {max_seq}",
        prompt.len()
    );

    draft.reset();
    target.reset();
    ctrl.reset_request();

    let mut committed: Vec<u32> = prompt.to_vec();
    let n0 = prompt.len();
    let mut rounds = Vec::new();

    'outer: while committed.len() - n0 < cfg.max_new {
        if cfg.stop_at_eos && committed.last() == Some(&EOS) {
            break;
        }
        let c = committed.len();
        let headroom = max_seq.saturating_sub(c + 2);
        if headroom < 1 {
            break;
        }
        let gamma = cfg.gamma_max.min(headroom);

        ctrl.session_start(rng);

        // --- draft session: catch up on committed suffix, then propose
        let t_draft = Instant::now();
        let mut sig = draft.block(&committed[draft.cur()..], draft.cur())?;
        let mut proposals: Vec<u32> = Vec::with_capacity(gamma);
        let mut sig_rows: Vec<TokenSignals> = Vec::new();
        loop {
            let last = *sig.last().expect("block returns >=1 row");
            proposals.push(last.argmax);
            sig_rows.push(last);
            let idx = proposals.len() - 1;
            if proposals.len() >= gamma || ctrl.should_stop(&last, idx, rng) {
                break;
            }
            sig = draft.block(&[last.argmax], c + proposals.len() - 1)?;
        }
        let draft_ns = t_draft.elapsed().as_nanos() as u64;

        // --- verification: one parallel target block over the unprocessed
        // committed suffix + all proposals. Row off+i predicts position
        // c+i, so it both checks proposals[i] and supplies the bonus token.
        let t_verify = Instant::now();
        let tc = target.cur();
        let mut inputs: Vec<u32> = committed[tc..].to_vec();
        inputs.extend_from_slice(&proposals);
        let vsig = target.block(&inputs, tc)?;
        let off = c - 1 - tc;
        let mut m = 0;
        while m < proposals.len() && vsig[off + m].argmax == proposals[m] {
            m += 1;
        }
        let bonus = vsig[off + m].argmax;
        let verify_ns = t_verify.elapsed().as_nanos() as u64;

        committed.extend_from_slice(&proposals[..m]);
        committed.push(bonus);
        target.rollback(c + m);
        draft.rollback(c + m);

        ctrl.on_verify(m, proposals.len());
        rounds.push(RoundStat {
            drafted: proposals.len(),
            accepted: m,
            arm: ctrl.current_arm(),
            draft_ns,
            verify_ns,
            signals: if cfg.collect_signals { sig_rows } else { Vec::new() },
        });

        if cfg.stop_at_eos && bonus == EOS {
            break 'outer;
        }
    }

    // note: the final round may overshoot max_new; full rounds are kept
    // (matches the python reference decoder — verification is atomic)
    Ok(GenResult {
        tokens: committed,
        prompt_len: n0,
        rounds,
        wall_ns: t_start.elapsed().as_nanos() as u64,
    })
}

/// Plain target-only greedy decoding (the correctness oracle and the
/// "no speculation" latency reference).
pub fn greedy(
    target: &mut dyn LanguageModel,
    prompt: &[u32],
    cfg: &GenConfig,
) -> anyhow::Result<GenResult> {
    let t_start = Instant::now();
    target.reset();
    let mut committed = prompt.to_vec();
    let n0 = prompt.len();
    let max_seq = target.max_seq();
    while committed.len() - n0 < cfg.max_new && committed.len() + 1 < max_seq {
        let sig = target.block(&committed[target.cur()..], target.cur())?;
        let nxt = sig.last().unwrap().argmax;
        committed.push(nxt);
        if cfg.stop_at_eos && nxt == EOS {
            break;
        }
    }
    Ok(GenResult {
        tokens: committed,
        prompt_len: n0,
        rounds: vec![],
        wall_ns: t_start.elapsed().as_nanos() as u64,
    })
}
