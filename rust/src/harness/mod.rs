//! Experiment harness: workloads, the measurement runner, and one runner
//! per paper table/figure (DESIGN.md §5).

pub mod experiments;
pub mod runner;
pub mod workload;

pub use experiments::{run_experiment, ExpOpts};
pub use runner::{run_method, run_probe, Backend, CatStats, MethodResult};
pub use workload::{load_suite, poisson_arrivals, sim_suite, WorkItem};
