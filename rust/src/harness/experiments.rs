//! Experiment runners — one per paper table/figure (DESIGN.md §5).
//!
//! Every runner prints the paper-shaped table/series to stdout and writes
//! machine-readable JSON under `results/`. Invoke via
//! `tapout exp --id <table2|table3|table4|table5|fig2|fig3|fig4|fig5|fig6|abl-arms|tune>`.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::models::Manifest;
use crate::runtime::Runtime;
use crate::spec::MethodSpec;
use crate::util::stats::Welford;
use crate::util::table::{fmt, Table};
use crate::util::Json;

use super::runner::{run_method, run_probe, Backend, MethodResult};
use super::workload::{load_suite, sim_suite, WorkItem};

/// Global experiment options (CLI flags).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub artifacts: PathBuf,
    pub results: PathBuf,
    /// "pjrt" or "sim"
    pub backend: String,
    /// workload scale multiplier (1.0 = defaults below)
    pub scale: f64,
    pub gamma_max: usize,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            artifacts: PathBuf::from("artifacts"),
            results: PathBuf::from("results"),
            backend: "pjrt".into(),
            scale: 1.0,
            gamma_max: 128,
        }
    }
}

/// Simulator stand-ins for the four paper model pairs (draft quality,
/// relative cost) when --backend sim is selected.
fn sim_pair_params(pair: &str) -> (f32, f64) {
    match pair {
        "pair-a" => (0.90, 1.0 / 16.0), // ~ Llama-3 1B/8B
        "pair-b" => (0.90, 1.0 / 40.0), // ~ Llama-3 1B/70B
        "pair-c" => (0.62, 1.0 / 24.0), // ~ Gemma3 270M/27B (weak draft)
        _ => (0.72, 1.0 / 40.0),        // ~ OLMo-2 1B/32B (misaligned)
    }
}

struct Ctx {
    opts: ExpOpts,
    manifest: Option<Manifest>,
    runtime: Option<Runtime>,
}

impl Ctx {
    fn new(opts: ExpOpts) -> Result<Ctx> {
        std::fs::create_dir_all(&opts.results).ok();
        let (manifest, runtime) = if opts.backend == "pjrt" {
            (
                Some(Manifest::load(&opts.artifacts)?),
                Some(Runtime::cpu().context("PJRT client")?),
            )
        } else {
            (None, None)
        };
        Ok(Ctx { opts, manifest, runtime })
    }

    fn backend(&self, pair: &str) -> Result<Backend> {
        if self.opts.backend == "pjrt" {
            Backend::pjrt(self.manifest.as_ref().unwrap(), self.runtime.as_ref().unwrap(), pair)
        } else {
            let (q, c) = sim_pair_params(pair);
            Ok(Backend::Sim { quality: q, rel_cost: c })
        }
    }

    fn suite(&self, name: &str, per_cat_default: usize, max_new: usize) -> Result<Vec<WorkItem>> {
        let per_cat = ((per_cat_default as f64 * self.opts.scale).round() as usize).max(1);
        if self.opts.backend == "pjrt" {
            let m = self.manifest.as_ref().unwrap();
            // suites have different category counts; humaneval is a
            // single category so it gets a larger per-cat multiplier
            let cats = match name {
                "humaneval" => 8,
                "mtbench" => 8,
                _ => 13,
            };
            let mut items = load_suite(m, name, per_cat * cats)?;
            for it in &mut items {
                it.max_new = it.max_new.min(max_new);
            }
            Ok(items)
        } else {
            Ok(sim_suite(name, per_cat * 4, max_new))
        }
    }

    fn save(&self, id: &str, json: Json) -> Result<()> {
        let path = self.opts.results.join(format!("{id}.json"));
        std::fs::write(&path, json.render())?;
        println!("\n[results -> {}]", path.display());
        Ok(())
    }

    fn method(&self, name: &str) -> MethodSpec {
        MethodSpec::parse(name, &self.opts.artifacts.display().to_string()).unwrap()
    }
}

/// Shared table emitter: method rows × (m, %, s-wall, s-cost).
fn emit_method_table(
    title: &str,
    results: &[MethodResult],
    baseline_idx: usize,
) -> (String, Json) {
    let base = &results[baseline_idx];
    let mut t = Table::new(&["Method", "Tuning?", "m", "%", "s (wall)", "s (cost)"]);
    let mut arr = Vec::new();
    for r in results {
        let tot = r.total();
        t.row(vec![
            r.method.clone(),
            if r.tuning_required { "Yes" } else { "No" }.into(),
            fmt(tot.mean_accepted(), 2),
            fmt(tot.acceptance_rate(), 2),
            fmt(r.speedup_vs(base), 2),
            fmt(r.cost_speedup_vs(base), 2),
        ]);
        arr.push(r.to_json(Some(base)));
    }
    let rendered = format!("\n## {title}\n\n{}", t.render());
    println!("{rendered}");
    (rendered, Json::Arr(arr))
}

// ---------------------------------------------------------------------------
// Table 2 + Fig 3: reward-formulation ablation (seq UCB1, r_simple vs r_blend)
// ---------------------------------------------------------------------------

fn exp_table2_fig3(ctx: &Ctx) -> Result<()> {
    let items = ctx.suite("specbench", 4, 96)?;
    let backend = ctx.backend("pair-a")?;
    let g = ctx.opts.gamma_max;

    let base = run_method(&backend, &items, &ctx.method("static-6"), g, false)?;
    let simple = run_method(&backend, &items, &ctx.method("seq-ucb1:rsimple"), g, false)?;
    let blend = run_method(&backend, &items, &ctx.method("seq-ucb1"), g, false)?;

    // Table 2: per-category % and s for both rewards. `s` uses the
    // cost-model speedup (paper-comparable); wall speedups go to JSON.
    let mut t = Table::new(&[
        "Category", "% (r_simple)", "s (r_simple)", "% (r_blend)", "s (r_blend)",
    ]);
    let mut cats: Vec<&String> = base.per_category.keys().collect();
    cats.sort();
    for c in &cats {
        let s_pct = simple.per_category.get(*c).map(|x| x.acceptance_rate()).unwrap_or(0.0);
        let b_pct = blend.per_category.get(*c).map(|x| x.acceptance_rate()).unwrap_or(0.0);
        t.row(vec![
            (*c).clone(),
            fmt(s_pct, 2),
            fmt(simple.cost_speedup_vs_cat(&base, c), 2),
            fmt(b_pct, 2),
            fmt(blend.cost_speedup_vs_cat(&base, c), 2),
        ]);
    }
    println!("\n## Table 2 — reward formulation (Seq UCB1, pair-a, specbench)\n");
    println!("{}", t.render());

    // Fig 3: speculated-length distributions
    let hist = |r: &MethodResult| {
        let mut h = crate::util::stats::Histogram::new(0.0, 64.0, 16);
        for c in r.per_category.values() {
            for &l in &c.drafted_lengths {
                h.push(l as f64);
            }
        }
        h
    };
    let hs = hist(&simple);
    let hb = hist(&blend);
    println!("## Fig 3 — speculated length |X| distribution");
    println!("  r_simple: {}  (n={})", hs.sparkline(), hs.total());
    println!("  r_blend : {}  (n={})", hb.sparkline(), hb.total());
    let mean = |r: &MethodResult| {
        let mut w = Welford::new();
        for c in r.per_category.values() {
            for &l in &c.drafted_lengths {
                w.push(l as f64);
            }
        }
        w
    };
    let (ws, wb) = (mean(&simple), mean(&blend));
    println!(
        "  mean |X|: r_simple {:.2}  r_blend {:.2}  (paper: r_simple drafts aggressively)",
        ws.mean(),
        wb.mean()
    );

    let mut out = Json::obj();
    out.set("table2", Json::Arr(vec![
        simple.to_json(Some(&base)),
        blend.to_json(Some(&base)),
    ]));
    let mut f3 = Json::obj();
    f3.set("r_simple_bins", Json::Arr(hs.bins.iter().map(|&b| Json::Num(b as f64)).collect()));
    f3.set("r_blend_bins", Json::Arr(hb.bins.iter().map(|&b| Json::Num(b as f64)).collect()));
    f3.set("r_simple_mean_len", ws.mean());
    f3.set("r_blend_mean_len", wb.mean());
    out.set("fig3", f3);
    ctx.save("table2_fig3", out)
}

// ---------------------------------------------------------------------------
// Fig 4: UCB1 vs UCB-Tuned speedup per category
// ---------------------------------------------------------------------------

fn exp_fig4(ctx: &Ctx) -> Result<()> {
    let items = ctx.suite("specbench", 4, 96)?;
    let backend = ctx.backend("pair-a")?;
    let g = ctx.opts.gamma_max;

    let base = run_method(&backend, &items, &ctx.method("static-6"), g, false)?;
    let ucb1 = run_method(&backend, &items, &ctx.method("seq-ucb1"), g, false)?;
    let tuned = run_method(&backend, &items, &ctx.method("seq-ucb-tuned"), g, false)?;

    let mut t = Table::new(&["Category", "s UCB1", "s UCB-Tuned"]);
    let mut wins = 0;
    let mut cats: Vec<&String> = base.per_category.keys().collect();
    cats.sort();
    for c in &cats {
        let s1 = ucb1.cost_speedup_vs_cat(&base, c);
        let s2 = tuned.cost_speedup_vs_cat(&base, c);
        if s1 >= s2 {
            wins += 1;
        }
        t.row(vec![(*c).clone(), fmt(s1, 2), fmt(s2, 2)]);
    }
    println!("\n## Fig 4 — UCB1 vs UCB-Tuned (pair-a, specbench)\n");
    println!("{}", t.render());
    println!("UCB1 >= UCB-Tuned in {wins}/{} categories (paper: all)", cats.len());

    let mut out = Json::obj();
    out.set("ucb1", ucb1.to_json(Some(&base)));
    out.set("ucb_tuned", tuned.to_json(Some(&base)));
    ctx.save("fig4", out)
}

// ---------------------------------------------------------------------------
// Fig 2: draft sqrt-entropy by position for accepted tokens
// ---------------------------------------------------------------------------

fn exp_fig2(ctx: &Ctx) -> Result<()> {
    let items = ctx.suite("specbench", 4, 96)?;
    let backend = ctx.backend("pair-a")?;

    // probe with fixed long drafts so every position is observed
    let traces = run_probe(&backend, &items, &MethodSpec::Static(16), 16)?;

    // mean sqrt-entropy at accepted positions, by draft position, split
    // coding vs non-coding
    let mut series: BTreeMap<&str, Vec<Welford>> = BTreeMap::new();
    series.insert("coding", vec![Welford::new(); 16]);
    series.insert("non-coding", vec![Welford::new(); 16]);
    for (item, r) in &traces {
        let key = if item.category == "coding" { "coding" } else { "non-coding" };
        let ws = series.get_mut(key).unwrap();
        for round in &r.rounds {
            for (i, sig) in round.signals.iter().enumerate().take(round.accepted) {
                ws[i].push(sig.sqrt_entropy as f64);
            }
        }
    }

    println!("\n## Fig 2 — draft sqrt(H) by draft position (accepted tokens, pair-a)\n");
    let mut out = Json::obj();
    for (key, ws) in &series {
        let vals: Vec<f64> = ws.iter().map(|w| w.mean()).collect();
        let counts: Vec<f64> = ws.iter().map(|w| w.count() as f64).collect();
        println!(
            "  {key:<11} pos 1..8: {}",
            vals.iter().take(8).map(|v| format!("{v:.2}")).collect::<Vec<_>>().join(" ")
        );
        let mut sj = Json::obj();
        sj.set("mean_sqrt_entropy", vals.clone());
        sj.set("counts", counts);
        out.set(key, sj);
    }
    let c0 = series["coding"].iter().take(6).map(|w| w.mean()).sum::<f64>() / 6.0;
    let n0 = series["non-coding"].iter().take(6).map(|w| w.mean()).sum::<f64>() / 6.0;
    println!("  mean over first 6 positions: coding {c0:.3} vs non-coding {n0:.3} (paper: coding ≪ non-coding)");

    // supplementary: per-category mean sqrt-entropy of accepted tokens —
    // TinyBench's deterministic *copy* grammars (extraction/translation/
    // rag) are the low-entropy analog; toy char-level "code" carries
    // random identifiers (see EXPERIMENTS.md Fig. 2 discussion)
    let mut per_cat: BTreeMap<String, Welford> = BTreeMap::new();
    for (item, r) in &traces {
        let w = per_cat.entry(item.category.clone()).or_insert_with(Welford::new);
        for round in &r.rounds {
            for sig in round.signals.iter().take(round.accepted) {
                w.push(sig.sqrt_entropy as f64);
            }
        }
    }
    let mut cj = Json::obj();
    println!("  per-category mean sqrt(H) at accepted tokens:");
    for (c, w) in &per_cat {
        println!("    {c:<16} {:.3}  (n={})", w.mean(), w.count());
        cj.set(c, w.mean());
    }
    out.set("per_category_mean", cj);
    ctx.save("fig2", out)
}

// ---------------------------------------------------------------------------
// Table 3: main results (4 pairs × 8 methods × mtbench/humaneval)
// ---------------------------------------------------------------------------

fn exp_table3(ctx: &Ctx) -> Result<()> {
    let methods = MethodSpec::all_paper_methods();
    let pairs = ["pair-a", "pair-b", "pair-c", "pair-d"];
    let g = ctx.opts.gamma_max;
    let mut out = Json::obj();

    for pair in pairs {
        let backend = ctx.backend(pair)?;
        for suite in ["mtbench", "humaneval"] {
            let items = ctx.suite(suite, 3, 96)?;
            let mut results = Vec::new();
            for m in &methods {
                results.push(run_method(&backend, &items, &ctx.method(m), g, false)?);
            }
            let (_, json) =
                emit_method_table(&format!("Table 3 — {pair} on {suite}"), &results, 0);
            out.set(&format!("{pair}/{suite}"), json);
        }
    }
    ctx.save("table3", out)
}

// ---------------------------------------------------------------------------
// Table 4: SpecDec++ (training-based) vs bandits, pair-a, specbench
// ---------------------------------------------------------------------------

fn exp_table4(ctx: &Ctx) -> Result<()> {
    anyhow::ensure!(
        ctx.opts.backend == "pjrt",
        "table4 needs the trained SpecDec++ classifier (pjrt backend)"
    );
    let items = ctx.suite("specbench", 4, 96)?;
    let backend = ctx.backend("pair-a")?;
    let g = ctx.opts.gamma_max;
    let names = ["static-6", "specdec++", "seq-ts", "seq-ucb1", "token-ts", "token-ucb1"];
    let mut results = Vec::new();
    for m in names {
        results.push(run_method(&backend, &items, &ctx.method(m), g, false)?);
    }
    let (_, json) =
        emit_method_table("Table 4 — SpecDec++ vs TapOut (pair-a, specbench)", &results, 0);
    ctx.save("table4", json.into_obj("rows"))
}

// ---------------------------------------------------------------------------
// Table 5: SpecBench across all pairs (Appendix A.3)
// ---------------------------------------------------------------------------

fn exp_table5(ctx: &Ctx) -> Result<()> {
    let methods = MethodSpec::all_paper_methods();
    let g = ctx.opts.gamma_max;
    let mut out = Json::obj();
    for pair in ["pair-a", "pair-b", "pair-c", "pair-d"] {
        let backend = ctx.backend(pair)?;
        let items = ctx.suite("specbench", 2, 96)?;
        let mut results = Vec::new();
        for m in &methods {
            results.push(run_method(&backend, &items, &ctx.method(m), g, false)?);
        }
        let (_, json) = emit_method_table(&format!("Table 5 — {pair} on specbench"), &results, 0);
        out.set(pair, json);
    }
    ctx.save("table5", out)
}

// ---------------------------------------------------------------------------
// Figs 5 & 6: arm-value progression (interpretability)
// ---------------------------------------------------------------------------

fn exp_fig5(ctx: &Ctx) -> Result<()> {
    arm_value_progression(ctx, "pair-a", &["mtbench", "humaneval"], "fig5")
}

fn exp_fig6(ctx: &Ctx) -> Result<()> {
    arm_value_progression(ctx, "pair-c", &["humaneval"], "fig6")
}

fn arm_value_progression(ctx: &Ctx, pair: &str, suites: &[&str], id: &str) -> Result<()> {
    let backend = ctx.backend(pair)?;
    let g = ctx.opts.gamma_max;
    let mut out = Json::obj();
    for suite in suites {
        let items = ctx.suite(suite, 6, 96)?;
        let r = run_method(&backend, &items, &ctx.method("seq-ucb1"), g, true)?;
        println!("\n## {id} — Seq UCB1 arm values, {pair} on {suite} ({} sessions)\n", r.value_history.len());
        let names = r.arm_names.clone();
        if let Some(last) = r.value_history.last() {
            let mut ranked: Vec<(usize, f64)> =
                last.iter().copied().enumerate().collect();
            ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            for (i, v) in &ranked {
                println!("  {:<22} μ = {v:.3}", names[*i]);
            }
            let spread = ranked[0].1 - ranked[ranked.len() - 1].1;
            println!("  value spread: {spread:.3}");
        }
        let mut sj = Json::obj();
        sj.set("arm_names", Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()));
        sj.set(
            "history",
            Json::Arr(r.value_history.iter().map(|v| Json::from(v.clone())).collect()),
        );
        out.set(suite, sj);
    }
    ctx.save(id, out)
}

// ---------------------------------------------------------------------------
// Appendix A.2 ablation: one arm per technique vs multi-threshold pool
// ---------------------------------------------------------------------------

fn exp_abl_arms(ctx: &Ctx) -> Result<()> {
    let items = ctx.suite("specbench", 4, 96)?;
    let backend = ctx.backend("pair-a")?;
    let g = ctx.opts.gamma_max;
    let base = run_method(&backend, &items, &ctx.method("static-6"), g, false)?;
    let single = run_method(&backend, &items, &ctx.method("seq-ucb1"), g, false)?;
    let multi = run_method(&backend, &items, &ctx.method("seq-ucb1:multi"), g, false)?;
    let (s1, s2) = (single.speedup_vs(&base), multi.speedup_vs(&base));
    println!("\n## A.2 — arm-pool ablation (pair-a, specbench)\n");
    println!("  one arm per technique (5 arms):   s = {s1:.3}");
    println!("  multi-threshold pool (13 arms):   s = {s2:.3}");
    println!("  single/multi = {:.2} (paper: single pool ~12% stronger)", s1 / s2.max(1e-9));
    let mut out = Json::obj();
    out.set("single", single.to_json(Some(&base)));
    out.set("multi", multi.to_json(Some(&base)));
    ctx.save("abl_arms", out)
}

// ---------------------------------------------------------------------------
// Baseline threshold tuning (the paper's §4.2 grid-search protocol)
// ---------------------------------------------------------------------------

fn exp_tune(ctx: &Ctx) -> Result<()> {
    let items = ctx.suite("specbench", 2, 96)?;
    let backend = ctx.backend("pair-a")?;
    let g = ctx.opts.gamma_max;
    let base = run_method(&backend, &items, &MethodSpec::Static(6), g, false)?;

    let grids: Vec<(&str, Vec<MethodSpec>)> = vec![
        ("svip", vec![0.3, 0.45, 0.6, 0.8, 1.0].into_iter().map(MethodSpec::Svip).collect()),
        ("max-conf", vec![0.5, 0.65, 0.8, 0.9].into_iter().map(MethodSpec::MaxConf).collect()),
        ("logit-margin", vec![0.1, 0.2, 0.35, 0.5].into_iter().map(MethodSpec::LogitMargin).collect()),
        ("svip-diff", vec![0.1, 0.2, 0.3, 0.45].into_iter().map(MethodSpec::SvipDiff).collect()),
    ];

    let mut out = Json::obj();
    println!("\n## Baseline threshold grid search (pair-a, specbench)\n");
    let mut t = Table::new(&["Technique", "Best threshold", "s (wall)"]);
    for (name, grid) in grids {
        let mut best: Option<(String, f64)> = None;
        let mut all = Vec::new();
        for spec in grid {
            let r = run_method(&backend, &items, &spec, g, false)?;
            let s = r.speedup_vs(&base);
            all.push((spec.label(), s));
            if best.as_ref().map(|(_, b)| s > *b).unwrap_or(true) {
                best = Some((format!("{spec:?}"), s));
            }
        }
        let (lbl, s) = best.unwrap();
        t.row(vec![name.into(), lbl.clone(), fmt(s, 2)]);
        let mut gj = Json::obj();
        for (l, sv) in all {
            gj.set(&l, sv);
        }
        out.set(name, gj);
    }
    println!("{}", t.render());
    ctx.save("tune", out)
}

// ---------------------------------------------------------------------------

trait IntoObj {
    fn into_obj(self, key: &str) -> Json;
}

impl IntoObj for Json {
    fn into_obj(self, key: &str) -> Json {
        let mut o = Json::obj();
        o.set(key, self);
        o
    }
}

pub fn run_experiment(id: &str, opts: ExpOpts) -> Result<()> {
    let ctx = Ctx::new(opts)?;
    match id {
        "fig2" => exp_fig2(&ctx),
        "table2" | "fig3" | "table2_fig3" => exp_table2_fig3(&ctx),
        "fig4" => exp_fig4(&ctx),
        "table3" => exp_table3(&ctx),
        "table4" => exp_table4(&ctx),
        "table5" => exp_table5(&ctx),
        "fig5" => exp_fig5(&ctx),
        "fig6" => exp_fig6(&ctx),
        "abl-arms" => exp_abl_arms(&ctx),
        "tune" => exp_tune(&ctx),
        "all" => {
            for id in ["fig2", "table2", "fig4", "table3", "table4", "table5", "fig5", "fig6", "abl-arms"] {
                run_experiment(id, ctx.opts.clone())?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment id: {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_opts() -> ExpOpts {
        ExpOpts {
            backend: "sim".into(),
            scale: 0.5,
            results: std::env::temp_dir().join("tapout-test-results"),
            ..ExpOpts::default()
        }
    }

    #[test]
    fn sim_experiments_run_end_to_end() {
        for id in ["table2", "fig4", "abl-arms"] {
            run_experiment(id, sim_opts()).unwrap();
        }
    }

    #[test]
    fn fig2_probe_runs_on_sim() {
        run_experiment("fig2", sim_opts()).unwrap();
    }
}
