//! Workloads for the experiment harness and the serving benches.
//!
//! PJRT runs use the TinyBench prompt suites from artifacts/prompts.json
//! (the SpecBench / MT-Bench / HumanEval / Alpaca analogs, DESIGN.md §3);
//! simulator runs synthesize position-indexed scenarios with the same
//! category labels. Poisson arrivals drive the serving benchmark.

use anyhow::Result;

use crate::models::{Manifest, PromptEntry};
use crate::util::Rng;

/// One unit of work: an encoded prompt plus metadata.
#[derive(Clone, Debug)]
pub struct WorkItem {
    pub category: String,
    pub prompt: Vec<u32>,
    pub text: String,
    pub max_new: usize,
    /// deterministic per-item seed (drives simulator scenarios + TS)
    pub seed: u64,
}

/// Load a prompt suite from the artifacts, encoded and seeded.
pub fn load_suite(manifest: &Manifest, suite: &str, limit: usize) -> Result<Vec<WorkItem>> {
    let prompts = manifest.prompts(suite)?;
    Ok(materialize(manifest, &prompts, suite, limit))
}

fn materialize(
    manifest: &Manifest,
    prompts: &[PromptEntry],
    suite: &str,
    limit: usize,
) -> Vec<WorkItem> {
    let mut out = Vec::new();
    // interleave categories so truncation by `limit` keeps coverage
    let mut by_cat: Vec<Vec<&PromptEntry>> = Vec::new();
    for p in prompts {
        match by_cat.iter_mut().find(|v| v[0].category == p.category) {
            Some(v) => v.push(p),
            None => by_cat.push(vec![p]),
        }
    }
    let mut idx = 0;
    'outer: loop {
        let mut any = false;
        for cat in &by_cat {
            if let Some(p) = cat.get(idx) {
                any = true;
                let mut prompt = vec![crate::spec::BOS];
                prompt.extend(manifest.encode(&p.text));
                out.push(WorkItem {
                    category: p.category.clone(),
                    prompt,
                    text: p.text.clone(),
                    max_new: p.max_new,
                    seed: hash_seed(suite, out.len()),
                });
                if out.len() >= limit {
                    break 'outer;
                }
            }
        }
        if !any {
            break;
        }
        idx += 1;
    }
    out
}

fn hash_seed(suite: &str, i: usize) -> u64 {
    crate::util::fnv1a(suite.bytes().map(u64::from)) ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Simulator workload with the same category structure as a suite.
pub fn sim_suite(suite: &str, per_cat: usize, max_new: usize) -> Vec<WorkItem> {
    let cats: Vec<&str> = match suite {
        "humaneval" => vec!["coding"],
        "mtbench" => vec![
            "writing", "roleplay", "reasoning", "math", "qa", "extraction", "stem",
            "humanities",
        ],
        _ => vec![
            "coding", "extraction", "humanities", "math", "math_reasoning", "qa", "rag",
            "reasoning", "roleplay", "stem", "summarization", "translation", "writing",
        ],
    };
    let mut out = Vec::new();
    for rep in 0..per_cat {
        for &c in &cats {
            let seed = hash_seed(suite, out.len()) ^ (rep as u64) << 32;
            // prompts are positional in the simulator; ~48-96 tokens
            let plen = 48 + (seed % 49) as usize;
            out.push(WorkItem {
                category: c.to_string(),
                prompt: (0..plen).map(|p| 3 + (p % 29) as u32).collect(),
                text: String::new(),
                max_new,
                seed,
            });
        }
    }
    out
}

/// Poisson arrival times (seconds) for `n` requests at `rate` req/s.
pub fn poisson_arrivals(seed: u64, n: usize, rate: f64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_suite_covers_categories() {
        let items = sim_suite("specbench", 2, 64);
        assert_eq!(items.len(), 26);
        assert!(items.iter().any(|w| w.category == "coding"));
        // deterministic
        let again = sim_suite("specbench", 2, 64);
        assert_eq!(items[5].seed, again[5].seed);
        assert!(items.iter().all(|w| w.prompt.len() >= 48));
    }

    #[test]
    fn humaneval_is_coding_only() {
        let items = sim_suite("humaneval", 3, 64);
        assert!(items.iter().all(|w| w.category == "coding"));
    }

    #[test]
    fn arrivals_monotone_with_right_rate() {
        let a = poisson_arrivals(1, 4000, 8.0);
        assert!(a.windows(2).all(|w| w[1] >= w[0]));
        let mean_gap = a.last().unwrap() / 4000.0;
        assert!((mean_gap - 0.125).abs() < 0.01, "{mean_gap}");
    }
}
