//! Method evaluation runner — the measurement core behind every table and
//! figure. Runs one `MethodSpec` over one workload with one model pair and
//! reports the paper's three metrics per category:
//!
//!   m  — mean accepted length per drafting session
//!   %  — acceptance rate (accepted / drafted)
//!   s  — speedup vs the Static-6 baseline *on the same prompts*
//!
//! Speedup is reported two ways (DESIGN.md §3): wall-clock (real, this
//! testbed) and an analytic cost model in target-row equivalents (corrects
//! for the draft/target FLOP-ratio difference vs the paper's model pairs).

use std::collections::BTreeMap;

use anyhow::Result;

use std::sync::Arc;

use crate::models::{sim::Scenario, LanguageModel, Manifest, ModelAssets, PjrtModel, SimModel};
use crate::runtime::Runtime;
use crate::spec::{generate, GenConfig, GenResult, MethodSpec};
use crate::util::{Json, Rng};

use super::workload::WorkItem;

/// Per-call dispatch overhead expressed in target-base token rows; used by
/// the analytic cost model (calibrated in EXPERIMENTS.md §Perf).
pub const OVERHEAD_ROWS: f64 = 2.0;

#[derive(Clone, Debug, Default)]
pub struct CatStats {
    pub requests: usize,
    pub rounds: usize,
    pub drafted: usize,
    pub accepted: usize,
    pub new_tokens: usize,
    pub wall_ns: u64,
    pub cost_rows: f64,
    /// per-session drafted lengths (Fig. 3 distribution)
    pub drafted_lengths: Vec<u32>,
}

impl CatStats {
    pub fn mean_accepted(&self) -> f64 {
        if self.rounds == 0 { 0.0 } else { self.accepted as f64 / self.rounds as f64 }
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 { 0.0 } else { self.accepted as f64 / self.drafted as f64 }
    }

    pub fn wall_per_token(&self) -> f64 {
        if self.new_tokens == 0 { f64::INFINITY } else { self.wall_ns as f64 / self.new_tokens as f64 }
    }

    pub fn cost_per_token(&self) -> f64 {
        if self.new_tokens == 0 { f64::INFINITY } else { self.cost_rows / self.new_tokens as f64 }
    }

    fn absorb(&mut self, r: &GenResult, cost_rows: f64) {
        self.requests += 1;
        self.rounds += r.rounds.len();
        self.drafted += r.drafted();
        self.accepted += r.accepted();
        self.new_tokens += r.new_tokens().len();
        self.wall_ns += r.wall_ns;
        self.cost_rows += cost_rows;
        for round in &r.rounds {
            self.drafted_lengths.push(round.drafted as u32);
        }
    }
}

#[derive(Clone, Debug)]
pub struct MethodResult {
    pub method: String,
    pub tuning_required: bool,
    pub per_category: BTreeMap<String, CatStats>,
    /// arm-value history (Seq bandits with tracking on)
    pub value_history: Vec<Vec<f64>>,
    pub arm_names: Vec<String>,
}

impl MethodResult {
    pub fn total(&self) -> CatStats {
        let mut t = CatStats::default();
        for c in self.per_category.values() {
            t.requests += c.requests;
            t.rounds += c.rounds;
            t.drafted += c.drafted;
            t.accepted += c.accepted;
            t.new_tokens += c.new_tokens;
            t.wall_ns += c.wall_ns;
            t.cost_rows += c.cost_rows;
        }
        t
    }

    /// wall-clock speedup vs a baseline run over the same workload
    pub fn speedup_vs(&self, baseline: &MethodResult) -> f64 {
        baseline.total().wall_per_token() / self.total().wall_per_token()
    }

    pub fn speedup_vs_cat(&self, baseline: &MethodResult, cat: &str) -> f64 {
        match (baseline.per_category.get(cat), self.per_category.get(cat)) {
            (Some(b), Some(m)) => b.wall_per_token() / m.wall_per_token(),
            _ => 0.0,
        }
    }

    /// cost-model speedup per category (the paper-comparable metric: our
    /// CPU testbed's fixed per-dispatch cost distorts wall-clock relative
    /// to the paper's GPU pairs — see DESIGN.md §3)
    pub fn cost_speedup_vs_cat(&self, baseline: &MethodResult, cat: &str) -> f64 {
        match (baseline.per_category.get(cat), self.per_category.get(cat)) {
            (Some(b), Some(m)) => b.cost_per_token() / m.cost_per_token(),
            _ => 0.0,
        }
    }

    /// cost-model speedup (target-row equivalents per token)
    pub fn cost_speedup_vs(&self, baseline: &MethodResult) -> f64 {
        baseline.total().cost_per_token() / self.total().cost_per_token()
    }

    pub fn to_json(&self, baseline: Option<&MethodResult>) -> Json {
        let mut o = Json::obj();
        o.set("method", self.method.as_str());
        o.set("tuning_required", self.tuning_required);
        let t = self.total();
        o.set("m", t.mean_accepted());
        o.set("accept_rate", t.acceptance_rate());
        o.set("wall_ns_per_token", t.wall_per_token());
        o.set("cost_rows_per_token", t.cost_per_token());
        if let Some(b) = baseline {
            o.set("speedup_wall", self.speedup_vs(b));
            o.set("speedup_cost", self.cost_speedup_vs(b));
        }
        let mut cats = Json::obj();
        for (c, st) in &self.per_category {
            let mut cj = Json::obj();
            cj.set("m", st.mean_accepted())
                .set("accept_rate", st.acceptance_rate())
                .set("requests", st.requests)
                .set("wall_ns_per_token", st.wall_per_token());
            if let Some(b) = baseline {
                cj.set("speedup_wall", self.speedup_vs_cat(b, c));
            }
            cats.set(c, cj);
        }
        o.set("categories", cats);
        o
    }
}

/// The backend a run executes on. PJRT assets (weights + compiled
/// executables) are shared across method runs via `Arc`.
pub enum Backend {
    /// real tiny LMs via PJRT artifacts
    Pjrt { draft: Arc<ModelAssets>, target: Arc<ModelAssets> },
    /// simulator pair: (draft quality, rel cost)
    Sim { quality: f32, rel_cost: f64 },
}

impl Backend {
    /// Load (once) the PJRT assets for a manifest pair and eagerly compile
    /// every shape bucket, so wall-clock comparisons between methods are
    /// never polluted by lazy XLA compilation (the first method measured
    /// would otherwise absorb all compile time).
    pub fn pjrt(manifest: &Manifest, runtime: &Runtime, pair: &str) -> Result<Backend> {
        let (dspec, tspec) = manifest.pair(pair)?;
        let (dname, tname) = (dspec.name.clone(), tspec.name.clone());
        let draft = ModelAssets::load(runtime, manifest, &dname)?;
        let target = ModelAssets::load(runtime, manifest, &tname)?;
        for assets in [&draft, &target] {
            let buckets = assets.exes.buckets();
            assets.exes.warmup(&buckets)?;
            let ebuckets = assets.extractors.buckets();
            assets.extractors.warmup(&ebuckets)?;
        }
        Ok(Backend::Pjrt { draft, target })
    }
}

/// Run a method over a workload. The controller (and its bandit memory)
/// lives across all requests — the paper's online setting.
pub fn run_method(
    backend: &Backend,
    items: &[WorkItem],
    method: &MethodSpec,
    gamma_max: usize,
    track_history: bool,
) -> Result<MethodResult> {
    let mut ctrl = method.build(gamma_max)?;
    ctrl.set_track_history(track_history);
    let mut rng = Rng::new(0x7A90 ^ items.len() as u64);

    let mut result = MethodResult {
        method: method.label(),
        tuning_required: method.tuning_required(),
        per_category: BTreeMap::new(),
        value_history: Vec::new(),
        arm_names: crate::policies::pool::arm_names(),
    };
    match backend {
        Backend::Pjrt { draft: da, target: ta } => {
            let mut draft = PjrtModel::new(da.clone())?;
            let mut target = PjrtModel::new(ta.clone())?;
            let (dc, tc) = (draft.rel_cost(), target.rel_cost());
            for item in items {
                let cfg = GenConfig {
                    max_new: item.max_new,
                    gamma_max,
                    stop_at_eos: true,
                    collect_signals: false,
                };
                let before = cost_of(&draft, &target, dc, tc);
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &item.prompt, &cfg)?;
                let spent = cost_of(&draft, &target, dc, tc) - before;
                result
                    .per_category
                    .entry(item.category.clone())
                    .or_default()
                    .absorb(&r, spent);
            }
        }
        Backend::Sim { quality, rel_cost } => {
            let sc0 = Scenario::new(0, "qa");
            let mut draft = SimModel::draft(sc0, *quality, *rel_cost);
            let mut target = SimModel::target(sc0);
            let (dc, tc) = (*rel_cost, 1.0);
            for item in items {
                let sc = Scenario::new(item.seed, &item.category);
                draft.set_scenario(sc);
                target.set_scenario(sc);
                let cfg = GenConfig {
                    max_new: item.max_new,
                    gamma_max,
                    stop_at_eos: false,
                    collect_signals: false,
                };
                let before = cost_of(&draft, &target, dc, tc);
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &item.prompt, &cfg)?;
                let spent = cost_of(&draft, &target, dc, tc) - before;
                result
                    .per_category
                    .entry(item.category.clone())
                    .or_default()
                    .absorb(&r, spent);
            }
        }
    }

    if let Some(h) = ctrl.value_history() {
        result.value_history = h.to_vec();
    }
    Ok(result)
}

fn cost_of(
    draft: &dyn LanguageModel,
    target: &dyn LanguageModel,
    dc: f64,
    tc: f64,
) -> f64 {
    let d = draft.cost();
    let t = target.cost();
    d.padded_rows as f64 * dc
        + t.padded_rows as f64 * tc
        + (d.calls + t.calls) as f64 * OVERHEAD_ROWS
}

/// Collect per-round traces (signals + accept labels) with a probe
/// controller — used by Fig. 2 and the interpretability experiments.
pub fn run_probe(
    backend: &Backend,
    items: &[WorkItem],
    method: &MethodSpec,
    gamma_max: usize,
) -> Result<Vec<(WorkItem, GenResult)>> {
    let mut ctrl = method.build(gamma_max)?;
    let mut rng = Rng::new(7);
    let mut out = Vec::new();
    match backend {
        Backend::Pjrt { draft: da, target: ta } => {
            let mut draft = PjrtModel::new(da.clone())?;
            let mut target = PjrtModel::new(ta.clone())?;
            for item in items {
                let cfg = GenConfig {
                    max_new: item.max_new,
                    gamma_max,
                    stop_at_eos: true,
                    collect_signals: true,
                };
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &item.prompt, &cfg)?;
                out.push((item.clone(), r));
            }
        }
        Backend::Sim { quality, rel_cost } => {
            let sc0 = Scenario::new(0, "qa");
            let mut draft = SimModel::draft(sc0, *quality, *rel_cost);
            let mut target = SimModel::target(sc0);
            for item in items {
                let sc = Scenario::new(item.seed, &item.category);
                draft.set_scenario(sc);
                target.set_scenario(sc);
                let cfg = GenConfig {
                    max_new: item.max_new,
                    gamma_max,
                    stop_at_eos: false,
                    collect_signals: true,
                };
                let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &item.prompt, &cfg)?;
                out.push((item.clone(), r));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::workload::sim_suite;

    #[test]
    fn sim_run_produces_sane_metrics() {
        let items = sim_suite("specbench", 1, 48);
        let backend = Backend::Sim { quality: 0.9, rel_cost: 0.05 };
        let m = MethodSpec::Static(6);
        let r = run_method(&backend, &items, &m, 128, false).unwrap();
        let t = r.total();
        assert_eq!(t.requests, items.len());
        assert!(t.new_tokens > 0);
        assert!(t.acceptance_rate() > 0.2 && t.acceptance_rate() <= 1.0);
        assert!(t.mean_accepted() <= 6.0);
        assert!(t.cost_rows > 0.0);
    }

    #[test]
    fn bandit_beats_nothing_burns_and_static_matches_k() {
        let items = sim_suite("specbench", 2, 48);
        let backend = Backend::Sim { quality: 0.9, rel_cost: 0.05 };
        let stat = run_method(&backend, &items, &MethodSpec::Static(6), 128, false).unwrap();
        // all sessions draft exactly 6 (or the tail-capped remainder)
        for c in stat.per_category.values() {
            assert!(c.drafted_lengths.iter().all(|&l| l <= 6));
        }
        let m = MethodSpec::parse("seq-ucb1", ".").unwrap();
        let ucb = run_method(&backend, &items, &m, 128, true).unwrap();
        assert!(!ucb.value_history.is_empty());
        assert!(ucb.total().new_tokens > 0);
    }

    #[test]
    fn probe_collects_signals() {
        let items = sim_suite("humaneval", 1, 32);
        let backend = Backend::Sim { quality: 0.85, rel_cost: 0.05 };
        let m = MethodSpec::Static(8);
        let traces = run_probe(&backend, &items, &m, 16).unwrap();
        assert!(traces.iter().any(|(_, r)| r.rounds.iter().any(|x| !x.signals.is_empty())));
    }
}
