//! Tiny CLI argument parser (offline stand-in for `clap`).
//!
//! Grammar: `tapout <subcommand> [--flag value | --switch] [positional...]`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value | --key value | --switch
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_flags_positional() {
        // note: a bare switch consumes a following non-flag token as its
        // value, so positionals must precede switches (documented grammar)
        let a = argv("exp --id table3 --scale 0.5 extra1 extra2 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("exp"));
        assert_eq!(a.str("id", ""), "table3");
        assert!((a.f64("scale", 1.0) - 0.5).abs() < 1e-12);
        assert!(a.bool("verbose"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = argv("serve --port=8080");
        assert_eq!(a.usize("port", 0), 8080);
        assert_eq!(a.usize("missing", 7), 7);
        assert!(!a.bool("missing"));
    }

    #[test]
    fn negative_number_values() {
        let a = argv("x --delta -3");
        // "-3" does not start with -- so it is consumed as the value
        assert!((a.f64("delta", 0.0) + 3.0).abs() < 1e-12);
    }
}
