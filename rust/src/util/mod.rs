//! Offline stand-ins for the crates the sealed image does not provide
//! (`rand`, `serde_json`, `clap`, `criterion`, `proptest`) — see
//! DESIGN.md §6. Everything here is dependency-free std-only code with its
//! own unit tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Rng;

/// FNV-1a-style deterministic mix over a value stream — the single
/// definition behind workload seeds (harness/workload.rs) and serving
/// scenario seeds (engine/request.rs), so the two can never drift.
pub fn fnv1a(vals: impl IntoIterator<Item = u64>) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in vals {
        h = (h ^ v).wrapping_mul(0x100000001b3);
    }
    h
}
