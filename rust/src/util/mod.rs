//! Offline stand-ins for the crates the sealed image does not provide
//! (`rand`, `serde_json`, `clap`, `criterion`, `proptest`) — see
//! DESIGN.md §6. Everything here is dependency-free std-only code with its
//! own unit tests.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Rng;
