//! Deterministic RNG + distributions (offline stand-in for `rand`/`rand_distr`).
//!
//! xoshiro256** seeded via SplitMix64; Box-Muller normals; Marsaglia-Tsang
//! gamma; beta via gamma ratio; exponential/Poisson helpers for the serving
//! workload generator. Everything is reproducible from a single u64 seed.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare: None,
        }
    }

    /// Derive an independent stream (for per-request / per-position RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Gamma(alpha, 1) via Marsaglia-Tsang (2000); alpha < 1 via boost.
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        debug_assert!(alpha > 0.0);
        if alpha < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(alpha + 1.0);
            let u = self.f64().max(1e-300);
            return g * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Beta(a, b) via the gamma ratio.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Exponential with rate lambda (Poisson inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Weighted index choice (weights need not be normalized).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut sum = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 20_000.0 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_alpha() {
        let mut r = Rng::new(3);
        for &alpha in &[0.5, 1.0, 2.5, 9.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(alpha)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.12 * alpha.max(1.0),
                "alpha {alpha} mean {mean}"
            );
        }
    }

    #[test]
    fn beta_in_unit_interval_with_right_mean() {
        let mut r = Rng::new(4);
        let (a, b) = (3.0, 7.0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.beta(a, b);
            assert!((0.0..=1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - a / (a + b)).abs() < 0.01);
    }

    #[test]
    fn weighted_prefers_heavy_arm() {
        let mut r = Rng::new(5);
        let w = [1.0, 8.0, 1.0];
        let mut c = [0usize; 3];
        for _ in 0..10_000 {
            c[r.weighted(&w)] += 1;
        }
        assert!(c[1] > c[0] * 4 && c[1] > c[2] * 4);
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
