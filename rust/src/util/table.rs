//! Markdown/ASCII table builder for experiment reports (stand-in for
//! pretty-printing crates). Emits GitHub-flavoured markdown that is also
//! readable raw in a terminal.

#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let emit = |cells: &[String], out: &mut String| {
            out.push('|');
            for i in 0..ncol {
                out.push(' ');
                out.push_str(&cells[i]);
                for _ in cells[i].len()..widths[i] {
                    out.push(' ');
                }
                out.push_str(" |");
            }
            out.push('\n');
        };
        emit(&self.headers, &mut out);
        out.push('|');
        for w in &widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('|');
        }
        out.push('\n');
        for row in &self.rows {
            emit(row, &mut out);
        }
        out
    }
}

/// Format a float with fixed decimals (tables use 2 almost everywhere).
pub fn fmt(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["method", "m", "s"]);
        t.row(vec!["Static-6".into(), "3.51".into(), "1.00".into()]);
        t.row(vec!["TapOut - Seq UCB1".into(), "5.29".into(), "1.15".into()]);
        let r = t.render();
        assert!(r.contains("| method "));
        assert!(r.lines().count() == 4);
        // all lines same length (alignment)
        let lens: Vec<usize> = r.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }
}
