//! Streaming statistics helpers (mean/variance via Welford, percentiles,
//! EMA, simple histogram) used by the metrics layer and the bench harness.

#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Collects raw samples for percentile queries (latency distributions).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Exponential moving average (AdaEDL / SpecDec++ state).
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    beta: f64,
    value: f64,
}

impl Ema {
    pub fn new(beta: f64, init: f64) -> Self {
        Ema { beta, value: init }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        self.value = self.beta * self.value + (1.0 - self.beta) * x;
        self.value
    }

    pub fn value(&self) -> f64 {
        self.value
    }
}

/// Fixed-bin histogram over [lo, hi) — used by the Fig. 2/3 emitters.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub under: u64,
    pub over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        Histogram { lo, hi, bins: vec![0; nbins], under: 0, over: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.bins.len();
            let b = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[b.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    /// Compact ASCII sparkline for terminal reports.
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.bins
            .iter()
            .map(|&c| GLYPHS[((c as f64 / max) * 7.0).round() as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Samples::new();
        for i in 0..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(50.0) - 50.0).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.0).abs() < 1e-9);
        assert!((s.percentile(0.0) - 0.0).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.9, 0.0);
        for _ in 0..200 {
            e.push(1.0);
        }
        assert!((e.value() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        h.push(-1.0);
        h.push(11.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.under, 1);
        assert_eq!(h.over, 1);
        assert_eq!(h.total(), 12);
    }
}
