//! Minimal JSON parser + writer (offline stand-in for `serde_json`).
//!
//! Covers everything the artifact manifest / prompts / results files need:
//! objects (insertion-ordered), arrays, strings with escapes, f64 numbers,
//! bools, null. Not a general-purpose validator — inputs are trusted
//! build-time artifacts.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // --- constructors --------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // --- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(m) => m.keys().map(|s| s.as_str()).collect(),
            _ => vec![],
        }
    }

    /// Convenience: `j.path(&["models", "draft-base", "kv_elems"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn f64s(&self) -> Vec<f64> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
            .unwrap_or_default()
    }

    // --- parsing ----------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    // --- writing ------------------------------------------------------------
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}
impl From<Vec<f64>> for Json {
    fn from(x: Vec<f64>) -> Json {
        Json::Arr(x.into_iter().map(Json::Num).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => expect(b, pos, "true").map(|_| Json::Bool(true)),
        b'f' => expect(b, pos, "false").map(|_| Json::Bool(false)),
        b'n' => expect(b, pos, "null").map(|_| Json::Null),
        _ => parse_num(b, pos),
    }
}

fn expect(b: &[u8], pos: &mut usize, word: &str) -> Result<(), String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(())
    } else {
        Err(format!("expected {word} at byte {pos}"))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let cp =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // copy a full utf-8 scalar
                let s = std::str::from_utf8(&b[*pos..]).map_err(|_| "bad utf8")?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(format!("expected , or ] at byte {pos}")),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at byte {pos}"));
        }
        *pos += 1;
        out.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(format!("expected , or }} at byte {pos}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["b", "c"]).unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("a").unwrap().f64s(), vec![1.0, 2.5, -300.0]);
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn builder() {
        let mut o = Json::obj();
        o.set("name", "x").set("n", 3usize).set("xs", vec![1.0, 2.0]);
        let v = Json::parse(&o.render()).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let r = v.render();
        assert_eq!(Json::parse(&r).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }
}
