//! Micro-benchmark harness (offline stand-in for `criterion`).
//!
//! Used by `benches/*.rs` (built with `harness = false`): warmup, timed
//! iterations, mean/std/p50/p99 reporting, and plain-text output that
//! `cargo bench` captures. Supports `TAPOUT_BENCH_FAST=1` for CI smoke.

use std::time::Instant;

use super::stats::Samples;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn fast_mode() -> bool {
    std::env::var("TAPOUT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Time `f` adaptively: run batches until ~`budget_ms` of samples exist.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    let budget_ms = if fast_mode() { budget_ms.min(50) } else { budget_ms };
    // warmup: one call, then estimate per-iter cost
    let t0 = Instant::now();
    f();
    let per_iter = t0.elapsed().as_nanos().max(1) as f64;
    let target_iters =
        ((budget_ms as f64 * 1e6) / per_iter).clamp(5.0, 100_000.0) as usize;

    let mut samples = Samples::new();
    // batch tiny functions so Instant overhead stays <1%
    let batch = (100.0 / per_iter * 1000.0).clamp(1.0, 10_000.0) as usize;
    let mut done = 0;
    while done < target_iters {
        let n = batch.min(target_iters - done);
        let t = Instant::now();
        for _ in 0..n {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / n as f64);
        done += n;
    }
    let mean = samples.mean();
    let var = samples
        .values()
        .iter()
        .map(|x| (x - mean) * (x - mean))
        .sum::<f64>()
        / samples.len().max(1) as f64;
    let res = BenchResult {
        name: name.to_string(),
        iters: done,
        mean_ns: mean,
        std_ns: var.sqrt(),
        p50_ns: samples.percentile(50.0),
        p99_ns: samples.percentile(99.0),
    };
    println!("{}", res.report());
    res
}

/// Group header for readable `cargo bench` output.
pub fn group(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut acc = 0u64;
        let r = bench("noop-ish", 20, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters >= 5);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with("s"));
    }
}
