//! Mini property-testing helper (offline stand-in for `proptest`).
//!
//! `forall(seed, cases, gen, check)` runs `check` on `cases` random inputs
//! produced by `gen`; on failure it reports the first failing case and the
//! seed that regenerates it. Shrinking-lite: retries the failing index with
//! "smaller" regenerated inputs is left to the generator (generators take
//! a `size` hint that grows over the run, so early failures are small).

use super::rng::Rng;

/// Run `check` on `cases` generated inputs. `gen` receives (rng, size)
/// where size ramps 0.1 -> 1.0 across the run so early cases are small.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng, f64) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let size = 0.1 + 0.9 * (i as f64 / cases.max(1) as f64);
        let mut case_rng = rng.fork(i as u64);
        let input = gen(&mut case_rng, size);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed at case {i}/{cases} (seed {seed}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall(
            1,
            200,
            |r, size| (r.below((10.0 * size) as usize + 2), r.f64()),
            |(n, x)| {
                if *x >= 0.0 && *x < 1.0 && *n < 12 {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(2, 50, |r, _| r.below(100), |n| {
            if *n < 90 { Ok(()) } else { Err(format!("{n} too big")) }
        });
    }
}
