//! Model backends: PJRT (artifact-backed tiny LMs) and the simulator.

pub mod manifest;
pub mod pjrt;
pub mod sim;
pub mod traits;

pub use manifest::{Manifest, ModelSpec, PromptEntry};
pub use pjrt::{ModelAssets, PjrtModel};
pub use sim::{sim_decode, sim_encode, sim_pair, Scenario, SimModel};
pub use traits::{LanguageModel, ModelCost};
