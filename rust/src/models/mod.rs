//! Model backends: PJRT (artifact-backed tiny LMs) and the simulator,
//! plus the batched-verification entry point ([`BatchItem`],
//! [`LanguageModel::block_batch`]) the serving engine's batcher drives.

pub mod faulty;
pub mod manifest;
pub mod pjrt;
pub mod sim;
pub mod traits;

pub use faulty::{FaultPlan, FaultStats, FaultyModel};
pub use manifest::{Manifest, ModelSpec, PromptEntry};
pub use pjrt::{ModelAssets, PjrtBatchVerifier, PjrtModel};
pub use sim::{preferred_drafter, sim_bucket, sim_decode, sim_encode, sim_pair, Scenario, SimModel};
pub use traits::{BatchItem, LanguageModel, ModelCost, PageView};
