//! Artifact manifest — the contract between `make artifacts` (python) and
//! the rust coordinator. Parses artifacts/manifest.json + prompts.json and
//! loads flat f32 weight files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

/// One model's entry in `artifacts/manifest.json`: geometry, weight file,
/// and the per-shape-bucket executables.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// manifest key ("draft-base", "target-base", ...)
    pub name: String,
    /// embedding width
    pub d_model: usize,
    /// transformer depth
    pub n_layers: usize,
    /// attention heads
    pub n_heads: usize,
    /// vocabulary size
    pub vocab: usize,
    /// KV-cache capacity in tokens
    pub max_seq: usize,
    /// flat f32 parameter count (weights file is param_count × 4 bytes)
    pub param_count: usize,
    /// KV region of the world buffer, in f32 elements
    pub kv_elems: usize,
    /// signal out-region of the world buffer, in f32 elements
    pub out_elems: usize,
    /// total world buffer size (kv_elems + out_elems)
    pub world_elems: usize,
    /// flat little-endian f32 weight file
    pub weights_path: PathBuf,
    /// sequence-length shape buckets the block executables are lowered for
    pub ladder: Vec<usize>,
    /// per-bucket single-sequence block executables (HLO text)
    pub hlo_files: HashMap<usize, PathBuf>,
    /// per-bucket signal extractor executables (world -> [k*8]); needed
    /// because PJRT CPU lacks CopyRawToHost (see aot.py lower_extract)
    pub extract_files: HashMap<usize, PathBuf>,
    /// batch-dimension buckets the batched verification executables are
    /// lowered for (docs/ARCHITECTURE.md §4); empty when the artifact set
    /// ships no batched executables — the PJRT batch verifier then falls
    /// back to per-sequence forwards
    pub batch_ladder: Vec<usize>,
    /// batched block executables keyed (batch bucket -> row bucket -> HLO
    /// file); each takes `weights, world×B, tokens[B*K], starts[B]`
    pub batch_files: HashMap<usize, HashMap<usize, PathBuf>>,
}

/// One prompt of a TinyBench suite (`artifacts/prompts.json`).
#[derive(Clone, Debug)]
pub struct PromptEntry {
    /// workload category label ("coding", "qa", ...)
    pub category: String,
    /// prompt text (char-level tokenizer input)
    pub text: String,
    /// decode budget for this prompt
    pub max_new: usize,
}

/// Parsed `artifacts/manifest.json` — the artifact directory's index.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// artifact directory the manifest was loaded from
    pub root: PathBuf,
    /// tokenizer vocabulary size
    pub vocab: usize,
    /// global KV capacity ceiling
    pub max_seq: usize,
    /// signal row width (must equal `signals::SIG_WIDTH`)
    pub sig_width: usize,
    /// char-level tokenizer alphabet (index + 3 = token id)
    pub alphabet: String,
    /// models by manifest key
    pub models: HashMap<String, ModelSpec>,
    /// paper-analog pairs: name -> (draft, target)
    pub pairs: Vec<(String, (String, String))>,
    /// optional drafter pools (docs/ARCHITECTURE.md §17): pair name ->
    /// ordered draft-model keys the selection layer chooses among. Absent
    /// pairs fall back to a pool of one (the pair's own draft model), so
    /// every pre-pool manifest stays valid unchanged.
    pub pools: HashMap<String, Vec<String>>,
}

impl Manifest {
    /// Parse `<dir>/manifest.json` (run `make artifacts` to produce it).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let need = |k: &str| -> Result<&Json> {
            j.get(k).ok_or_else(|| anyhow::anyhow!("manifest missing key {k}"))
        };

        let mut models = HashMap::new();
        if let Json::Obj(m) = need("models")? {
            for (name, mj) in m {
                let geti = |k: &str| -> Result<usize> {
                    mj.get(k)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow::anyhow!("model {name} missing {k}"))
                };
                let mut hlo_files = HashMap::new();
                if let Some(Json::Obj(h)) = mj.get("hlo") {
                    for (k, v) in h {
                        hlo_files.insert(
                            k.parse::<usize>().map_err(|_| anyhow::anyhow!("bad bucket {k}"))?,
                            dir.join(v.as_str().unwrap_or_default()),
                        );
                    }
                }
                let mut extract_files = HashMap::new();
                if let Some(Json::Obj(h)) = mj.get("extract") {
                    for (k, v) in h {
                        extract_files.insert(
                            k.parse::<usize>().map_err(|_| anyhow::anyhow!("bad bucket {k}"))?,
                            dir.join(v.as_str().unwrap_or_default()),
                        );
                    }
                }
                let ladder = mj
                    .get("ladder")
                    .map(|l| l.f64s().iter().map(|&x| x as usize).collect())
                    .unwrap_or_default();
                // optional batched-verification artifacts (absent in seed
                // artifact sets; the engine falls back gracefully)
                let mut batch_files: HashMap<usize, HashMap<usize, PathBuf>> = HashMap::new();
                if let Some(Json::Obj(bmap)) = mj.get("hlo_batch") {
                    for (b, inner) in bmap {
                        let b: usize =
                            b.parse().map_err(|_| anyhow::anyhow!("bad batch bucket {b}"))?;
                        let mut per_k = HashMap::new();
                        if let Json::Obj(kmap) = inner {
                            for (k, v) in kmap {
                                per_k.insert(
                                    k.parse::<usize>()
                                        .map_err(|_| anyhow::anyhow!("bad bucket {k}"))?,
                                    dir.join(v.as_str().unwrap_or_default()),
                                );
                            }
                        }
                        batch_files.insert(b, per_k);
                    }
                }
                let mut batch_ladder: Vec<usize> = mj
                    .get("batch_ladder")
                    .map(|l| l.f64s().iter().map(|&x| x as usize).collect())
                    .unwrap_or_else(|| batch_files.keys().copied().collect());
                batch_ladder.sort_unstable();
                models.insert(
                    name.clone(),
                    ModelSpec {
                        name: name.clone(),
                        d_model: geti("d_model")?,
                        n_layers: geti("n_layers")?,
                        n_heads: geti("n_heads")?,
                        vocab: geti("vocab")?,
                        max_seq: geti("max_seq")?,
                        param_count: geti("param_count")?,
                        kv_elems: geti("kv_elems")?,
                        out_elems: geti("out_elems")?,
                        world_elems: geti("world_elems")?,
                        weights_path: dir.join(
                            mj.get("weights").and_then(|x| x.as_str()).unwrap_or_default(),
                        ),
                        ladder,
                        hlo_files,
                        extract_files,
                        batch_ladder,
                        batch_files,
                    },
                );
            }
        }

        let mut pairs = Vec::new();
        if let Some(Json::Obj(p)) = j.get("pairs") {
            for (name, v) in p {
                let a = v.at(0).and_then(|x| x.as_str()).unwrap_or_default().to_string();
                let b = v.at(1).and_then(|x| x.as_str()).unwrap_or_default().to_string();
                pairs.push((name.clone(), (a, b)));
            }
        }
        pairs.sort();

        // optional drafter pools: {"pair-a": ["draft-base", "draft-tiny"]};
        // every listed model must exist so a bad manifest fails at load,
        // not at first route
        let mut pools = HashMap::new();
        if let Some(Json::Obj(p)) = j.get("pools").or_else(|| j.get("drafter_pools")) {
            for (pair, v) in p {
                let names: Vec<String> = v
                    .as_arr()
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str())
                            .map(|s| s.to_string())
                            .collect()
                    })
                    .unwrap_or_default();
                anyhow::ensure!(!names.is_empty(), "pool for {pair} is empty");
                for n in &names {
                    anyhow::ensure!(models.contains_key(n), "pool for {pair} names unknown model {n}");
                }
                pools.insert(pair.clone(), names);
            }
        }

        Ok(Manifest {
            root: dir.to_path_buf(),
            vocab: need("vocab")?.as_usize().unwrap_or(96),
            max_seq: need("max_seq")?.as_usize().unwrap_or(384),
            sig_width: need("sig_width")?.as_usize().unwrap_or(8),
            alphabet: need("alphabet")?.as_str().unwrap_or_default().to_string(),
            models,
            pairs,
            pools,
        })
    }

    /// Spec for one model by manifest key.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest"))
    }

    /// (draft, target) specs for a named pair ("pair-a", ...).
    pub fn pair(&self, name: &str) -> Result<(&ModelSpec, &ModelSpec)> {
        let (d, t) = self
            .pairs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow::anyhow!("pair {name} not in manifest"))?;
        Ok((self.model(d)?, self.model(t)?))
    }

    /// Ordered drafter pool for a named pair (docs/ARCHITECTURE.md §17):
    /// the manifest's `pools` entry when present, otherwise a pool of one
    /// holding the pair's own draft model — index 0 is always the drafter
    /// the pre-pool engine would have used.
    pub fn drafter_pool(&self, name: &str) -> Result<Vec<&ModelSpec>> {
        if let Some(names) = self.pools.get(name) {
            return names.iter().map(|n| self.model(n)).collect();
        }
        let (d, _) = self.pair(name)?;
        Ok(vec![d])
    }

    /// Flat little-endian f32 weight file.
    pub fn load_weights(&self, spec: &ModelSpec) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&spec.weights_path)
            .with_context(|| format!("reading {}", spec.weights_path.display()))?;
        anyhow::ensure!(
            bytes.len() == spec.param_count * 4,
            "weight file {} has {} bytes, expected {}",
            spec.weights_path.display(),
            bytes.len(),
            spec.param_count * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    // --- tokenizer (char-level; mirrors python corpus.py) -----------------

    /// Text → token ids (unknown characters are dropped).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars()
            .filter_map(|c| self.alphabet.find(c).map(|i| (i + 3) as u32))
            .collect()
    }

    /// Token ids → text (ids outside the alphabet are dropped).
    pub fn decode(&self, ids: &[u32]) -> String {
        let chars: Vec<char> = self.alphabet.chars().collect();
        ids.iter()
            .filter_map(|&i| chars.get((i as usize).wrapping_sub(3)).copied())
            .collect()
    }

    // --- prompt suites ----------------------------------------------------

    /// Load one prompt suite from `<root>/prompts.json`.
    pub fn prompts(&self, suite: &str) -> Result<Vec<PromptEntry>> {
        let text = std::fs::read_to_string(self.root.join("prompts.json"))
            .context("reading prompts.json")?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("prompts.json: {e}"))?;
        let arr = j
            .get(suite)
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("suite {suite} not in prompts.json"))?;
        Ok(arr
            .iter()
            .map(|p| PromptEntry {
                category: p.get("category").and_then(|x| x.as_str()).unwrap_or("").into(),
                text: p.get("text").and_then(|x| x.as_str()).unwrap_or("").into(),
                max_new: p.get("max_new").and_then(|x| x.as_usize()).unwrap_or(160),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn tokenizer_roundtrip_without_artifacts() {
        // independent of artifacts: construct a manifest by hand
        let m = Manifest {
            root: PathBuf::new(),
            vocab: 96,
            max_seq: 384,
            sig_width: 8,
            alphabet: "abc 123".into(),
            models: HashMap::new(),
            pairs: vec![],
            pools: HashMap::new(),
        };
        let ids = m.encode("cab 31");
        assert_eq!(ids, vec![5, 3, 4, 6, 9, 7]);
        assert_eq!(m.decode(&ids), "cab 31");
        // unknown chars are dropped
        assert_eq!(m.encode("a!b"), vec![3, 4]);
    }

    #[test]
    fn drafter_pool_defaults_to_the_pair_draft() {
        let spec = |name: &str| ModelSpec {
            name: name.into(),
            d_model: 8,
            n_layers: 1,
            n_heads: 1,
            vocab: 96,
            max_seq: 64,
            param_count: 0,
            kv_elems: 0,
            out_elems: 0,
            world_elems: 0,
            weights_path: PathBuf::new(),
            ladder: vec![1],
            hlo_files: HashMap::new(),
            extract_files: HashMap::new(),
            batch_ladder: vec![],
            batch_files: HashMap::new(),
        };
        let mut models = HashMap::new();
        for n in ["draft-base", "draft-tiny", "target-base"] {
            models.insert(n.to_string(), spec(n));
        }
        let mut m = Manifest {
            root: PathBuf::new(),
            vocab: 96,
            max_seq: 384,
            sig_width: 8,
            alphabet: "abc".into(),
            models,
            pairs: vec![("pair-a".into(), ("draft-base".into(), "target-base".into()))],
            pools: HashMap::new(),
        };
        // no pools entry: pool of one, the pair's own draft
        let pool = m.drafter_pool("pair-a").unwrap();
        assert_eq!(pool.len(), 1);
        assert_eq!(pool[0].name, "draft-base");
        // with a pools entry, order is preserved
        m.pools.insert("pair-a".into(), vec!["draft-base".into(), "draft-tiny".into()]);
        let pool = m.drafter_pool("pair-a").unwrap();
        assert_eq!(pool.iter().map(|s| s.name.as_str()).collect::<Vec<_>>(), vec![
            "draft-base",
            "draft-tiny"
        ]);
        assert!(m.drafter_pool("pair-z").is_err(), "unknown pair still errors");
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.len() >= 2);
        assert_eq!(m.pairs.len(), 4);
        for (_, spec) in &m.models {
            assert_eq!(spec.world_elems, spec.kv_elems + spec.out_elems);
            assert!(!spec.ladder.is_empty());
        }
        let (d, t) = m.pair("pair-a").unwrap();
        assert!(d.param_count < t.param_count);
        let prompts = m.prompts("specbench").unwrap();
        assert!(!prompts.is_empty());
    }
}
