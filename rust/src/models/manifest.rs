//! Artifact manifest — the contract between `make artifacts` (python) and
//! the rust coordinator. Parses artifacts/manifest.json + prompts.json and
//! loads flat f32 weight files.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::Json;

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub param_count: usize,
    pub kv_elems: usize,
    pub out_elems: usize,
    pub world_elems: usize,
    pub weights_path: PathBuf,
    pub ladder: Vec<usize>,
    pub hlo_files: HashMap<usize, PathBuf>,
    /// per-bucket signal extractor executables (world -> [k*8]); needed
    /// because PJRT CPU lacks CopyRawToHost (see aot.py lower_extract)
    pub extract_files: HashMap<usize, PathBuf>,
}

#[derive(Clone, Debug)]
pub struct PromptEntry {
    pub category: String,
    pub text: String,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab: usize,
    pub max_seq: usize,
    pub sig_width: usize,
    pub alphabet: String,
    pub models: HashMap<String, ModelSpec>,
    /// paper-analog pairs: name -> (draft, target)
    pub pairs: Vec<(String, (String, String))>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        let need = |k: &str| -> Result<&Json> {
            j.get(k).ok_or_else(|| anyhow::anyhow!("manifest missing key {k}"))
        };

        let mut models = HashMap::new();
        if let Json::Obj(m) = need("models")? {
            for (name, mj) in m {
                let geti = |k: &str| -> Result<usize> {
                    mj.get(k)
                        .and_then(|x| x.as_usize())
                        .ok_or_else(|| anyhow::anyhow!("model {name} missing {k}"))
                };
                let mut hlo_files = HashMap::new();
                if let Some(Json::Obj(h)) = mj.get("hlo") {
                    for (k, v) in h {
                        hlo_files.insert(
                            k.parse::<usize>().map_err(|_| anyhow::anyhow!("bad bucket {k}"))?,
                            dir.join(v.as_str().unwrap_or_default()),
                        );
                    }
                }
                let mut extract_files = HashMap::new();
                if let Some(Json::Obj(h)) = mj.get("extract") {
                    for (k, v) in h {
                        extract_files.insert(
                            k.parse::<usize>().map_err(|_| anyhow::anyhow!("bad bucket {k}"))?,
                            dir.join(v.as_str().unwrap_or_default()),
                        );
                    }
                }
                let ladder = mj
                    .get("ladder")
                    .map(|l| l.f64s().iter().map(|&x| x as usize).collect())
                    .unwrap_or_default();
                models.insert(
                    name.clone(),
                    ModelSpec {
                        name: name.clone(),
                        d_model: geti("d_model")?,
                        n_layers: geti("n_layers")?,
                        n_heads: geti("n_heads")?,
                        vocab: geti("vocab")?,
                        max_seq: geti("max_seq")?,
                        param_count: geti("param_count")?,
                        kv_elems: geti("kv_elems")?,
                        out_elems: geti("out_elems")?,
                        world_elems: geti("world_elems")?,
                        weights_path: dir.join(
                            mj.get("weights").and_then(|x| x.as_str()).unwrap_or_default(),
                        ),
                        ladder,
                        hlo_files,
                        extract_files,
                    },
                );
            }
        }

        let mut pairs = Vec::new();
        if let Some(Json::Obj(p)) = j.get("pairs") {
            for (name, v) in p {
                let a = v.at(0).and_then(|x| x.as_str()).unwrap_or_default().to_string();
                let b = v.at(1).and_then(|x| x.as_str()).unwrap_or_default().to_string();
                pairs.push((name.clone(), (a, b)));
            }
        }
        pairs.sort();

        Ok(Manifest {
            root: dir.to_path_buf(),
            vocab: need("vocab")?.as_usize().unwrap_or(96),
            max_seq: need("max_seq")?.as_usize().unwrap_or(384),
            sig_width: need("sig_width")?.as_usize().unwrap_or(8),
            alphabet: need("alphabet")?.as_str().unwrap_or_default().to_string(),
            models,
            pairs,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model {name} not in manifest"))
    }

    pub fn pair(&self, name: &str) -> Result<(&ModelSpec, &ModelSpec)> {
        let (d, t) = self
            .pairs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow::anyhow!("pair {name} not in manifest"))?;
        Ok((self.model(d)?, self.model(t)?))
    }

    /// Flat little-endian f32 weight file.
    pub fn load_weights(&self, spec: &ModelSpec) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&spec.weights_path)
            .with_context(|| format!("reading {}", spec.weights_path.display()))?;
        anyhow::ensure!(
            bytes.len() == spec.param_count * 4,
            "weight file {} has {} bytes, expected {}",
            spec.weights_path.display(),
            bytes.len(),
            spec.param_count * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    // --- tokenizer (char-level; mirrors python corpus.py) -----------------

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars()
            .filter_map(|c| self.alphabet.find(c).map(|i| (i + 3) as u32))
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let chars: Vec<char> = self.alphabet.chars().collect();
        ids.iter()
            .filter_map(|&i| chars.get((i as usize).wrapping_sub(3)).copied())
            .collect()
    }

    // --- prompt suites ----------------------------------------------------

    pub fn prompts(&self, suite: &str) -> Result<Vec<PromptEntry>> {
        let text = std::fs::read_to_string(self.root.join("prompts.json"))
            .context("reading prompts.json")?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("prompts.json: {e}"))?;
        let arr = j
            .get(suite)
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("suite {suite} not in prompts.json"))?;
        Ok(arr
            .iter()
            .map(|p| PromptEntry {
                category: p.get("category").and_then(|x| x.as_str()).unwrap_or("").into(),
                text: p.get("text").and_then(|x| x.as_str()).unwrap_or("").into(),
                max_new: p.get("max_new").and_then(|x| x.as_usize()).unwrap_or(160),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn tokenizer_roundtrip_without_artifacts() {
        // independent of artifacts: construct a manifest by hand
        let m = Manifest {
            root: PathBuf::new(),
            vocab: 96,
            max_seq: 384,
            sig_width: 8,
            alphabet: "abc 123".into(),
            models: HashMap::new(),
            pairs: vec![],
        };
        let ids = m.encode("cab 31");
        assert_eq!(ids, vec![5, 3, 4, 6, 9, 7]);
        assert_eq!(m.decode(&ids), "cab 31");
        // unknown chars are dropped
        assert_eq!(m.encode("a!b"), vec![3, 4]);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.len() >= 2);
        assert_eq!(m.pairs.len(), 4);
        for (_, spec) in &m.models {
            assert_eq!(spec.world_elems, spec.kv_elems + spec.out_elems);
            assert!(!spec.ladder.is_empty());
        }
        let (d, t) = m.pair("pair-a").unwrap();
        assert!(d.param_count < t.param_count);
        let prompts = m.prompts("specbench").unwrap();
        assert!(!prompts.is_empty());
    }
}
