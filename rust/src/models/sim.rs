//! Simulator backend — synthetic correlated draft/target pairs.
//!
//! Stands in for workloads the sealed environment cannot run at scale
//! (DESIGN.md §3): bandit-horizon experiments, property tests and benches
//! over millions of tokens. The simulator reproduces the *structure* the
//! paper exploits:
//!
//!   * each request has a deterministic "script" — the target's greedy
//!     continuation (a pure function of seed × position, so KV rollback is
//!     trivially consistent);
//!   * a per-category difficulty profile τ(p) (coding ≪ prose, decaying
//!     with position — the Fig. 2 shape);
//!   * the draft agrees with the script with probability that *rises* as
//!     its entropy falls, so the L1 stop signals carry real information,
//!     exactly like a trained draft model.
//!
//! Implements the same `LanguageModel` trait as the PJRT backend; signal
//! rows are computed with `TokenSignals::from_logits` over a synthetic
//! 32-way distribution so every invariant (top1 ≥ top2, margin, entropy
//! consistency) holds exactly.

use crate::models::traits::{BatchItem, LanguageModel, ModelCost, PageView};
use crate::signals::TokenSignals;

/// Size of the simulator's synthetic vocabulary (ids 0..SIM_VOCAB; 0-2 are
/// reserved for PAD/BOS/EOS as in the artifact tokenizer).
pub const SIM_VOCAB: u32 = 32;
const SIM_MAX_SEQ: usize = 4096;

/// Shape buckets the simulated batched forward pads to — the sim analog of
/// the manifest's batch/sequence ladders (docs/ARCHITECTURE.md §4). Both
/// the batch dimension and the row dimension round up to the next bucket,
/// and the waste lands in `ModelCost::padded_rows` so the engine's
/// pad-waste gauge is exercised without PJRT.
pub const SIM_BATCH_BUCKETS: [usize; 5] = [1, 2, 4, 8, 16];

/// Smallest simulator bucket ≥ `n` (saturating at the largest bucket times
/// a power of two, so arbitrarily large batches still bucket).
pub fn sim_bucket(n: usize) -> usize {
    for &b in &SIM_BATCH_BUCKETS {
        if b >= n {
            return b;
        }
    }
    // beyond the ladder: next power of two keeps padding bounded < 2x
    n.next_power_of_two()
}

/// Difficulty profile of a workload category.
#[derive(Clone, Copy, Debug)]
pub struct CategoryProfile {
    /// baseline difficulty in [0, 1] (coding low, prose high)
    pub base: f32,
    /// exponential decay of difficulty with position (entropy decays with
    /// generation length — paper Fig. 2)
    pub decay: f32,
    /// probability of a "hard burst" position (names, numbers, ...)
    pub burst_p: f32,
    /// additive difficulty of a burst position
    pub burst_mag: f32,
}

impl CategoryProfile {
    /// Difficulty profile for a TinyBench-style category label.
    pub fn for_category(cat: &str) -> CategoryProfile {
        match cat {
            "coding" => CategoryProfile { base: 0.06, decay: 0.004, burst_p: 0.04, burst_mag: 0.45 },
            "math" | "math_reasoning" => {
                CategoryProfile { base: 0.10, decay: 0.003, burst_p: 0.10, burst_mag: 0.55 }
            }
            "extraction" | "translation" | "rag" => {
                CategoryProfile { base: 0.13, decay: 0.003, burst_p: 0.07, burst_mag: 0.5 }
            }
            "qa" | "summarization" | "reasoning" | "stem" => {
                CategoryProfile { base: 0.22, decay: 0.002, burst_p: 0.09, burst_mag: 0.45 }
            }
            // writing / roleplay / humanities and default: open-ended prose
            _ => CategoryProfile { base: 0.34, decay: 0.001, burst_p: 0.11, burst_mag: 0.4 },
        }
    }

    /// Difficulty at absolute position p.
    pub fn tau(&self, seed: u64, p: usize) -> f32 {
        let decayed = self.base * (-(self.decay as f64) * p as f64).exp() as f32;
        let burst = if unit(seed, p as u64, 0xB00) < self.burst_p as f64 {
            self.burst_mag
        } else {
            0.0
        };
        (decayed + burst).clamp(0.0, 0.95)
    }
}

/// Deterministic rank of a category's difficulty base in the profile
/// table (coding 0 … open-ended prose 4) — the stable key per-drafter
/// acceptance profiles hang off.
fn base_rank(base: f32) -> usize {
    if base < 0.08 {
        0
    } else if base < 0.12 {
        1
    } else if base < 0.20 {
        2
    } else if base < 0.30 {
        3
    } else {
        4
    }
}

/// Which pooled drafter a category's acceptance profile favors
/// (docs/ARCHITECTURE.md §17): the drafter whose proposals the simulated
/// verify accepts most often on that category. Deterministic in
/// (category, pool size); `n <= 1` always answers 0. Benches and tests
/// use this to construct workloads where tenants provably prefer
/// *different* drafters.
pub fn preferred_drafter(category: &str, n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        base_rank(CategoryProfile::for_category(category).base) % n
    }
}

/// Salt mixed into a drafter's agreement/confidence hashes so pooled
/// drafters propose decorrelated streams; drafter 0 salts to 0, keeping
/// it bit-for-bit the legacy single-drafter stream.
fn drafter_salt(d: usize) -> u64 {
    (d as u64).wrapping_mul(0xD097_A57C_3D9E_3779)
}

/// Deterministic unit-interval hash of (seed, position, salt).
fn unit(seed: u64, p: u64, salt: u64) -> f64 {
    let mut z = seed
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(p.wrapping_mul(0xBF58476D1CE4E5B9))
        .wrapping_add(salt.wrapping_mul(0x94D049BB133111EB));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    ((z ^ (z >> 31)) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Shared per-request scenario: the script + difficulty.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    /// request seed (a pure function of the prompt, engine/request.rs)
    pub seed: u64,
    /// per-category difficulty profile
    pub profile: CategoryProfile,
}

impl Scenario {
    /// Scenario for one request: its seed plus the category profile.
    pub fn new(seed: u64, category: &str) -> Scenario {
        Scenario { seed, profile: CategoryProfile::for_category(category) }
    }

    /// The target's greedy continuation token at position p.
    pub fn script(&self, p: usize) -> u32 {
        3 + (unit(self.seed, p as u64, 0x5C27) * (SIM_VOCAB - 3) as f64) as u32
    }
}

/// One side of a simulated pair.
pub struct SimModel {
    scenario: Scenario,
    /// draft quality in [0,1]; None = this is the target
    quality: Option<f32>,
    cur: usize,
    cost: ModelCost,
    rel_cost: f64,
    name: String,
    /// cumulative prompt tokens adopted via shared KV pages
    /// (`LanguageModel::adopt_pages`, docs/ARCHITECTURE.md §13)
    adopted: u64,
    /// pooled drafter count (docs/ARCHITECTURE.md §17); 1 = the legacy
    /// single-drafter model, whose rows this pool reproduces exactly
    pool: usize,
    /// currently routed drafter for the single-sequence path
    /// ([`LanguageModel::set_drafter`]); batched items carry their own
    drafter: usize,
    /// reusable logit row for `row_at` — cleared and refilled per row so
    /// the padded-pass ladder stops allocating one `Vec` per signal row
    /// in the step-loop hot path (the churn the engine's
    /// `scratch_allocs` gauge watches); fixed at `SIM_VOCAB` entries, so
    /// it allocates exactly once per model
    logits: Vec<f32>,
}

impl SimModel {
    /// The simulated target model for `scenario`.
    pub fn target(scenario: Scenario) -> SimModel {
        SimModel {
            scenario,
            quality: None,
            cur: 0,
            cost: ModelCost::default(),
            rel_cost: 1.0,
            name: "sim-target".into(),
            adopted: 0,
            pool: 1,
            drafter: 0,
            logits: Vec::new(),
        }
    }

    /// `quality` ∈ [0,1]: probability scale of agreeing with the target
    /// in easy (τ=0) positions. rel_cost ≈ draft/target FLOP ratio.
    pub fn draft(scenario: Scenario, quality: f32, rel_cost: f64) -> SimModel {
        SimModel {
            scenario,
            quality: Some(quality),
            cur: 0,
            cost: ModelCost::default(),
            rel_cost,
            name: format!("sim-draft(q={quality})"),
            adopted: 0,
            pool: 1,
            drafter: 0,
            logits: Vec::new(),
        }
    }

    /// Host a pool of `n` seeded per-drafter acceptance profiles on this
    /// draft model (docs/ARCHITECTURE.md §17). Each category favors one
    /// drafter ([`preferred_drafter`]): the favored drafter's agreement
    /// quality rises, every other drafter's collapses, and each drafter's
    /// agreement/confidence hashes are salted apart so their proposal
    /// streams decorrelate. A pool of one (`n <= 1`) produces rows
    /// bit-for-bit identical to the plain draft model.
    pub fn with_drafters(mut self, n: usize) -> SimModel {
        self.pool = n.max(1);
        if self.pool > 1 {
            self.name = format!("{}[pool={n}]", self.name);
        }
        self
    }

    /// Reseat on a new request scenario (keeps cost counters).
    pub fn set_scenario(&mut self, scenario: Scenario) {
        self.scenario = scenario;
        self.cur = 0;
    }

    /// Effective agreement quality of pooled drafter `d` on scenario `s`:
    /// the base quality for a pool of one, boosted for the category's
    /// preferred drafter and collapsed otherwise.
    fn pool_quality(&self, q: f32, s: &Scenario, d: usize) -> f32 {
        if self.pool <= 1 {
            return q;
        }
        if d == base_rank(s.profile.base) % self.pool {
            (q + 0.08).min(0.98)
        } else {
            (q * 0.35).max(0.02)
        }
    }

    /// (agrees-with-script, agreement probability) of drafter `d` at
    /// position `p` — the pure core shared by [`row_at`](Self::row_at)
    /// and [`LanguageModel::score_drafters`], so scoring can never drift
    /// from what the rows actually proposed.
    fn draft_agreement(&self, s: &Scenario, p: usize, d: usize, q: f32) -> (bool, f64) {
        let tau = s.profile.tau(s.seed, p);
        let q = self.pool_quality(q, s, d);
        let a = (q as f64 * (1.0 - tau as f64)).clamp(0.0, 1.0);
        (unit(s.seed, p as u64, 0xA6EE ^ drafter_salt(d)) < a, a)
    }

    /// The deterministic wrong token (≠ script) drafter `d` proposes at a
    /// disagreeing position.
    fn wrong_token(s: &Scenario, p: usize, d: usize) -> u32 {
        let script_tok = s.script(p);
        let alt = 3
            + (unit(s.seed, p as u64, 0xBAD ^ drafter_salt(d)) * (SIM_VOCAB - 3) as f64) as u32;
        if alt == script_tok { (alt - 3 + 1) % (SIM_VOCAB - 3) + 3 } else { alt }
    }

    /// Signals for the prediction of position `p` (i.e. after processing
    /// the input at p-1) under this model's *current* scenario and
    /// currently routed drafter.
    fn row_for(&mut self, p: usize) -> TokenSignals {
        let s = self.scenario;
        let d = self.drafter;
        self.row_at(&s, p, d)
    }

    /// Signals for position `p` under an explicit scenario, proposed by
    /// pooled drafter `d` — the scenario-parametric core shared by the
    /// single-sequence path and the batched verification path (rows are
    /// a pure function of (scenario, quality, drafter, position), which
    /// is what makes batched and sequential verification byte-identical).
    fn row_at(&mut self, s: &Scenario, p: usize, d: usize) -> TokenSignals {
        let tau = s.profile.tau(s.seed, p);
        let script_tok = s.script(p);
        let (agree, conf) = match self.quality {
            None => {
                // target: confident, mildly affected by difficulty
                (true, 1.0 - 0.25 * tau as f64)
            }
            Some(q) => {
                // agreement probability falls with difficulty
                let (agrees, a) = self.draft_agreement(s, p, d, q);
                // confidence noisily tracks the agreement probability —
                // this is what makes entropy *informative* for stopping
                let noise = (unit(s.seed, p as u64, 0xC0F ^ drafter_salt(d)) - 0.5) * 0.12;
                (agrees, (0.18 + 0.80 * a + noise).clamp(0.05, 0.995))
            }
        };
        let argmax = if agree { script_tok } else { Self::wrong_token(s, p, d) };
        // synthesize an actual logit row: peak `conf`, runner-up, uniform
        // tail — refilled into the reusable scratch row, byte-identical
        // to building a fresh Vec (clear + resize writes every entry)
        let v = SIM_VOCAB as usize;
        let conf = conf as f32;
        let p2 = (1.0 - conf) * 0.5;
        let tail = (1.0 - conf - p2).max(1e-6) / (v - 2) as f32;
        self.logits.clear();
        self.logits.resize(v, tail.ln());
        let runner = (argmax as usize + 1 - 3) % (v - 3) + 3;
        self.logits[argmax as usize] = conf.ln();
        self.logits[runner] = p2.max(1e-6).ln();
        TokenSignals::from_logits(&self.logits)
    }

    /// The shared batched-pass core behind `block_batch` and
    /// `draft_batch`: one call, batch and row dimensions padded to the
    /// sim bucket ladder, rows computed per item scenario.
    fn batched_rows(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        anyhow::ensure!(!seqs.is_empty(), "empty batch");
        let kmax = seqs.iter().map(|s| s.tokens.len()).max().unwrap_or(0);
        anyhow::ensure!(kmax > 0, "empty block in batch");
        // pad batch and row dimensions to the sim bucket ladder; the
        // waste is what the engine's pad-waste gauges read
        let bb = sim_bucket(seqs.len());
        let kb = sim_bucket(kmax);
        self.cost.calls += 1;
        self.cost.rows += seqs.iter().map(|s| s.tokens.len() as u64).sum::<u64>();
        self.cost.padded_rows += (bb * kb) as u64;
        let mut out = Vec::with_capacity(seqs.len());
        for item in seqs {
            let sc = Scenario::new(item.seed, &item.category);
            let mut rows = Vec::with_capacity(item.tokens.len());
            for i in 0..item.tokens.len() {
                rows.push(self.row_at(&sc, item.start + i + 1, item.drafter));
            }
            out.push(rows);
        }
        Ok(out)
    }

    /// Capacity of the reusable logit scratch row — the bench's
    /// churn probe: after the first row it must pin at `SIM_VOCAB` and
    /// never grow again, however many padded passes run.
    pub fn scratch_capacity(&self) -> usize {
        self.logits.capacity()
    }
}

impl LanguageModel for SimModel {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn reset(&mut self) {
        self.cur = 0;
    }

    fn begin_request(&mut self, seed: u64, category: &str) {
        self.set_scenario(Scenario::new(seed, category));
    }

    /// Prefix reuse on the simulator (docs/ARCHITECTURE.md §12): reseat
    /// the scenario but keep the cursor at `min(cur, keep)` instead of 0.
    /// Valid because signal rows are a pure function of (scenario,
    /// position): the skipped positions' rows under the *new* scenario
    /// are never read by anyone (the engine re-feeds the last prompt
    /// token, so every row a decode consumes is computed fresh), which is
    /// exactly the guarantee a real KV cache gives for a matching token
    /// prefix.
    fn retain_prefix(&mut self, seed: u64, category: &str, keep: usize) -> usize {
        self.scenario = Scenario::new(seed, category);
        self.cur = self.cur.min(keep);
        self.cur
    }

    /// The simulator is adoptive (docs/ARCHITECTURE.md §13): its rows
    /// are pure functions of (scenario, position), so KV is
    /// content-addressed and a token-matching prefix computed under a
    /// *different* slot is exactly as valid as one this model computed
    /// itself.
    fn page_view(&self) -> PageView {
        PageView { adoptive: true, resident: self.cur, adopted_tokens: self.adopted }
    }

    /// Adopt shared pages on the simulator: reseat the scenario and set
    /// the cursor to the full `shared` residency — which may move the
    /// cursor *forward* past positions this model never computed. Valid
    /// for the same reason `retain_prefix` is: validity is token-content
    /// equality, not compute history, and every row a decode consumes is
    /// computed fresh (the engine re-feeds the last prompt token, so
    /// `shared < prompt_len` always leaves the seeding row to be
    /// produced under the new scenario).
    fn adopt_pages(&mut self, seed: u64, category: &str, local: usize, shared: usize) -> usize {
        debug_assert!(local <= shared, "shared residency covers the local prefix");
        self.scenario = Scenario::new(seed, category);
        // positions beyond `local` are vouched by shared pages, not by
        // anything this model computed — that difference is what the
        // adopted-tokens gauge measures
        self.adopted += shared.saturating_sub(local) as u64;
        self.cur = shared;
        self.cur
    }

    fn block(&mut self, tokens: &[u32], start: usize) -> anyhow::Result<Vec<TokenSignals>> {
        anyhow::ensure!(start == self.cur, "non-contiguous block: start {start} cur {}", self.cur);
        anyhow::ensure!(!tokens.is_empty(), "empty block");
        self.cost.calls += 1;
        self.cost.rows += tokens.len() as u64;
        self.cost.padded_rows += tokens.len() as u64;
        self.cur = start + tokens.len();
        // row i = prediction for position start+i+1
        Ok((0..tokens.len()).map(|i| self.row_for(start + i + 1)).collect())
    }

    /// Native batched forward: one padded pass over every item
    /// (docs/ARCHITECTURE.md §4). Rows are a pure function of
    /// (scenario, position), so the output is byte-identical to feeding
    /// each item through `block` on its own slot model; only the cost
    /// accounting differs — one call, shape-bucketed padding.
    fn block_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.batched_rows(seqs)
    }

    /// Native batched drafting (docs/ARCHITECTURE.md §11): the same
    /// padded pass as [`LanguageModel::block_batch`] — a drafting
    /// micro-round is just a ragged batch of per-sequence blocks, and on
    /// a draft-side model the rows carry the draft distribution.
    fn draft_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.batched_rows(seqs)
    }

    fn n_drafters(&self) -> usize {
        self.pool
    }

    fn set_drafter(&mut self, d: usize) {
        self.drafter = d.min(self.pool.saturating_sub(1));
    }

    /// Full-information scoring (docs/ARCHITECTURE.md §17): for each
    /// pooled drafter, the exact fraction of the committed `tokens` whose
    /// argmax that drafter's rows propose. Pure bookkeeping over the same
    /// `draft_agreement`/`wrong_token` core the rows are built from — no
    /// cursor movement, no cost counting, no randomness beyond the
    /// position hashes the rows themselves use.
    fn score_drafters(
        &mut self,
        seed: u64,
        category: &str,
        tokens: &[u32],
        start: usize,
    ) -> Vec<f64> {
        let n = self.pool;
        if tokens.is_empty() {
            return vec![1.0; n];
        }
        let q = self.quality.unwrap_or(1.0);
        let s = Scenario::new(seed, category);
        let mut out = Vec::with_capacity(n);
        for d in 0..n {
            let mut hits = 0usize;
            for (i, &tok) in tokens.iter().enumerate() {
                let p = start + i;
                let (agrees, _) = self.draft_agreement(&s, p, d, q);
                let proposed = if agrees { s.script(p) } else { Self::wrong_token(&s, p, d) };
                if proposed == tok {
                    hits += 1;
                }
            }
            out.push(hits as f64 / tokens.len() as f64);
        }
        out
    }

    fn cur(&self) -> usize {
        self.cur
    }

    fn rollback(&mut self, to: usize) {
        self.cur = self.cur.min(to);
    }

    fn max_seq(&self) -> usize {
        SIM_MAX_SEQ
    }

    fn cost(&self) -> ModelCost {
        self.cost
    }

    fn rel_cost(&self) -> f64 {
        self.rel_cost
    }
}

/// Convenience: a (draft, target) pair over a fresh scenario.
pub fn sim_pair(seed: u64, category: &str, quality: f32) -> (SimModel, SimModel) {
    let sc = Scenario::new(seed, category);
    (SimModel::draft(sc, quality, 1.0 / 20.0), SimModel::target(sc))
}

/// Text → sim-vocab tokens (the serving engine's codec on the simulator
/// backend; BOS not included). The mapping only needs to be deterministic:
/// sim outputs are driven by the scenario script, not the prompt content.
pub fn sim_encode(text: &str) -> Vec<u32> {
    text.bytes().map(|b| 3 + (b % (SIM_VOCAB as u8 - 3)) as u32).collect()
}

/// Sim tokens → printable text (lossy by construction; diagnostics only).
pub fn sim_decode(tokens: &[u32]) -> String {
    tokens
        .iter()
        .map(|&t| char::from(b'a' + (t.saturating_sub(3) % 26) as u8))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_rollback_consistent() {
        let sc = Scenario::new(42, "coding");
        let mut m = SimModel::draft(sc, 0.9, 0.05);
        let a = m.block(&[5, 6, 7, 8], 0).unwrap();
        m.rollback(2);
        let b = m.block(&[7, 8], 2).unwrap();
        assert_eq!(a[2..], b[..], "re-fed rows must match");
    }

    #[test]
    fn contiguity_enforced() {
        let sc = Scenario::new(1, "qa");
        let mut m = SimModel::target(sc);
        m.block(&[3], 0).unwrap();
        assert!(m.block(&[3], 5).is_err());
    }

    #[test]
    fn coding_easier_than_prose() {
        let mut agree_coding = 0;
        let mut agree_prose = 0;
        let n = 2000;
        for seed in 0..n {
            let (mut d, t) = sim_pair(seed, "coding", 0.9);
            let row = d.block(&[3], 0).unwrap()[0];
            if row.argmax == t.scenario.script(1) {
                agree_coding += 1;
            }
            let (mut d, t) = sim_pair(seed, "writing", 0.9);
            let row = d.block(&[3], 0).unwrap()[0];
            if row.argmax == t.scenario.script(1) {
                agree_prose += 1;
            }
        }
        assert!(
            agree_coding > agree_prose + n as i32 / 20,
            "coding {agree_coding} vs prose {agree_prose}"
        );
    }

    #[test]
    fn entropy_is_informative_about_agreement() {
        // split rows at the median entropy; low-entropy rows must agree
        // more often (that is what makes the stop signals informative)
        let mut rows = Vec::new();
        for seed in 0..3000u64 {
            let (mut d, t) = sim_pair(seed, "writing", 0.85);
            let row = d.block(&[3], 0).unwrap()[0];
            rows.push((row.sqrt_entropy, row.argmax == t.scenario.script(1)));
        }
        // compare the lowest vs highest entropy quartiles
        let mut ents: Vec<f32> = rows.iter().map(|r| r.0).collect();
        ents.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = ents[ents.len() / 4];
        let q3 = ents[3 * ents.len() / 4];
        let mut lo = (0, 0);
        let mut hi = (0, 0);
        for (e, agrees) in rows {
            if e <= q1 {
                lo.0 += agrees as i32;
                lo.1 += 1;
            } else if e >= q3 {
                hi.0 += agrees as i32;
                hi.1 += 1;
            }
        }
        let rate = |b: &(i32, i32)| b.0 as f64 / b.1.max(1) as f64;
        assert!(lo.1 > 100 && hi.1 > 100, "both buckets populated: {lo:?} {hi:?}");
        assert!(
            rate(&lo) > rate(&hi) + 0.1,
            "low-entropy agree {:.2} vs high {:.2}",
            rate(&lo),
            rate(&hi)
        );
    }

    #[test]
    fn target_signals_are_confident() {
        let sc = Scenario::new(7, "coding");
        let mut t = SimModel::target(sc);
        let rows = t.block(&[3, 4, 5], 0).unwrap();
        for r in rows {
            assert!(r.top1 > 0.5);
        }
    }

    #[test]
    fn batched_rows_match_sequential_rows() {
        // the batched verifier path must be byte-identical to driving each
        // sequence's own slot model through block()
        let items: Vec<BatchItem> = (0..3)
            .map(|i| BatchItem {
                seq: i,
                seed: 1000 + i as u64,
                category: ["coding", "qa", "writing"][i].into(),
                tokens: vec![3 + i as u32; 4 + i],
                start: 2 * i,
                drafter: 0,
            })
            .collect();
        let mut verifier = SimModel::target(Scenario::new(0, "qa"));
        let batched = verifier.block_batch(&items).unwrap();
        for (item, rows) in items.iter().zip(&batched) {
            let mut solo = SimModel::target(Scenario::new(item.seed, &item.category));
            // reach the item's start position contiguously, then feed it
            if item.start > 0 {
                solo.block(&vec![3; item.start], 0).unwrap();
            }
            let want = solo.block(&item.tokens, item.start).unwrap();
            assert_eq!(rows, &want, "seq {} diverged", item.seq);
        }
    }

    #[test]
    fn logit_scratch_allocates_once_and_stays_flat() {
        let mut m = SimModel::draft(Scenario::new(5, "qa"), 0.9, 0.05);
        assert_eq!(m.scratch_capacity(), 0, "lazy: nothing until the first row");
        m.block(&[3, 4, 5], 0).unwrap();
        let cap = m.scratch_capacity();
        assert_eq!(cap, SIM_VOCAB as usize);
        // hammer the padded-pass ladder: batched + sequential rows, many
        // iterations — the scratch must never grow again
        for round in 0..50usize {
            let items: Vec<BatchItem> = (0..4)
                .map(|i| BatchItem {
                    seq: i,
                    seed: i as u64,
                    category: "qa".into(),
                    tokens: vec![3; 1 + (round + i) % 7],
                    start: 0,
                    drafter: 0,
                })
                .collect();
            let mut fresh = SimModel::target(Scenario::new(round as u64, "qa"));
            fresh.block_batch(&items).unwrap();
            assert_eq!(fresh.scratch_capacity(), cap, "round {round}");
            m.block(&[6], 3 + round).unwrap();
            assert_eq!(m.scratch_capacity(), cap, "round {round}");
        }
    }

    #[test]
    fn batched_cost_counts_one_call_and_padding() {
        let items: Vec<BatchItem> = (0..3)
            .map(|i| BatchItem {
                seq: i,
                seed: i as u64,
                category: "qa".into(),
                tokens: vec![3; 5],
                start: 0,
                drafter: 0,
            })
            .collect();
        let mut verifier = SimModel::target(Scenario::new(0, "qa"));
        verifier.block_batch(&items).unwrap();
        let c = verifier.cost();
        assert_eq!(c.calls, 1, "one batched forward, not one per item");
        assert_eq!(c.rows, 15);
        // batch 3 -> bucket 4, rows 5 -> bucket 8
        assert_eq!(c.padded_rows, 32);
        assert!(verifier.block_batch(&[]).is_err());
    }

    #[test]
    fn sim_bucket_ladder() {
        assert_eq!(sim_bucket(1), 1);
        assert_eq!(sim_bucket(3), 4);
        assert_eq!(sim_bucket(16), 16);
        assert_eq!(sim_bucket(17), 32);
    }

    #[test]
    fn pool_of_one_is_byte_identical_to_the_plain_draft() {
        // the whole drafter layer must be inert at pool size 1: same
        // rows, same cost, same everything (docs §17 byte-identity)
        let sc = Scenario::new(99, "qa");
        let mut plain = SimModel::draft(sc, 0.9, 0.05);
        let mut pooled = SimModel::draft(sc, 0.9, 0.05).with_drafters(1);
        pooled.set_drafter(0);
        let a = plain.block(&[3, 4, 5, 6, 7], 0).unwrap();
        let b = pooled.block(&[3, 4, 5, 6, 7], 0).unwrap();
        assert_eq!(a, b);
        // and drafter 0 of a *multi* pool still draws the legacy salts:
        // its agreement stream is the legacy one, quality-shifted only
        assert_eq!(plain.score_drafters(99, "qa", &[3, 4], 1).len(), 1);
    }

    #[test]
    fn categories_favor_different_drafters_and_profiles_separate() {
        // with a pool of 2, coding and qa land on different preferred
        // drafters (base ranks 0 and 3), and each category accepts its
        // preferred drafter's proposals far more often
        assert_ne!(preferred_drafter("coding", 2), preferred_drafter("qa", 2));
        assert_eq!(preferred_drafter("anything", 1), 0);
        for cat in ["coding", "qa"] {
            let fav = preferred_drafter(cat, 2);
            let mut agree = [0u32; 2];
            let m = SimModel::draft(Scenario::new(0, cat), 0.9, 0.05).with_drafters(2);
            for seed in 0..800u64 {
                let s = Scenario::new(seed, cat);
                for d in 0..2 {
                    if m.draft_agreement(&s, 1, d, 0.9).0 {
                        agree[d] += 1;
                    }
                }
            }
            assert!(
                agree[fav] > agree[1 - fav] + 200,
                "{cat}: preferred {fav} must dominate ({agree:?})"
            );
        }
    }

    #[test]
    fn score_drafters_is_pure_and_matches_the_rows() {
        // the score must be exactly the argmax-agreement fraction of the
        // same rows block() produces, and scoring must not perturb the
        // model (cursor, cost) at all
        let seed = 1234u64;
        let cat = "math";
        let mut m = SimModel::draft(Scenario::new(seed, cat), 0.85, 0.05).with_drafters(3);
        let committed: Vec<u32> = {
            let s = Scenario::new(seed, cat);
            (1..=12).map(|p| s.script(p)).collect()
        };
        let cur0 = m.cur();
        let cost0 = m.cost();
        let scores = m.score_drafters(seed, cat, &committed, 1);
        assert_eq!(m.cur(), cur0, "scoring must not move the cursor");
        assert_eq!(m.cost(), cost0, "scoring must not count model cost");
        assert_eq!(scores.len(), 3);
        for (d, &sc) in scores.iter().enumerate() {
            assert!((0.0..=1.0).contains(&sc), "drafter {d}: {sc}");
            // recompute from the actual rows that drafter would emit
            let mut solo = SimModel::draft(Scenario::new(seed, cat), 0.85, 0.05).with_drafters(3);
            solo.set_drafter(d);
            let rows = solo.block(&vec![3; 12], 0).unwrap();
            let hits = rows
                .iter()
                .zip(&committed)
                .filter(|(r, &tok)| r.argmax == tok)
                .count();
            assert_eq!(sc, hits as f64 / 12.0, "drafter {d} score != row agreement");
        }
    }

    #[test]
    fn cost_counters_accumulate() {
        let sc = Scenario::new(7, "qa");
        let mut m = SimModel::target(sc);
        m.block(&[3, 4], 0).unwrap();
        m.block(&[5], 2).unwrap();
        assert_eq!(m.cost().calls, 2);
        assert_eq!(m.cost().rows, 3);
    }
}
