//! Fault injection at the [`LanguageModel`] boundary.
//!
//! [`FaultyModel`] wraps any backend and injects three failure shapes the
//! serving engine must survive (docs/TESTING.md):
//!
//!   * **errors** — a forward (`block` / `block_batch` / `draft_batch`)
//!     returns `Err` instead of rows, exactly like a device fault or an
//!     executor OOM;
//!   * **slow steps** — a forward reports extra virtual latency through
//!     [`FaultStats::delay_ns`] (the deterministic simulator's fake clock
//!     consumes it; real time is never slept, so tests stay fast);
//!   * **crashes** — a panic-equivalent: the model goes *sticky-broken*
//!     and every forward fails until the engine reseats it for a new
//!     request (`begin_request` / `reset` / `retain_prefix` /
//!     `adopt_pages` clear the condition, mirroring a process restart
//!     that reloads weights but loses sequence state).
//!
//! Reuse-path faults (`retain_prefix` / `adopt_pages`) degrade to a fresh
//! start — the wrapper reseats the inner model and reports zero resident
//! positions. That is always *lossless*: the engine takes the min of the
//! draft/target residencies and rolls cursors back, so a lost lease only
//! costs recomputed prefill rows, never wrong tokens.
//!
//! All fault decisions come from the plan's own deterministic RNG stream
//! (`util::Rng`), keyed per call — two runs over the same call sequence
//! inject byte-identical faults, which is what lets the sim harness
//! replay and shrink failing seeds (sim_harness/). Speculative forwards
//! (`speculate_batch`, docs/ARCHITECTURE.md §16) deliberately bypass the
//! RNG so enabling pipelining never shifts the fault stream; only the
//! sticky crash condition applies to them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::signals::TokenSignals;
use crate::util::Rng;

use super::traits::{BatchItem, LanguageModel, ModelCost, PageView};

/// Deterministic fault-injection plan for one [`FaultyModel`] (and, via
/// `EngineConfig::faults`, for every sim-backend model an engine boots).
/// `Default` is fault-free; [`FaultPlan::is_active`] gates all wrapping.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// RNG seed for the fault stream (forked per wrapped model)
    pub seed: u64,
    /// probability a forward returns `Err` (transient device fault)
    pub error_rate: f64,
    /// probability a forward is slow (virtual delay, no real sleep)
    pub slow_rate: f64,
    /// virtual latency a slow forward adds, in nanoseconds
    pub slow_ns: u64,
    /// probability a forward *crashes* the model (sticky-broken until the
    /// next request reseats it — the panic-equivalent failure)
    pub crash_rate: f64,
    /// probability a `retain_prefix`/`adopt_pages` lease is lost (the
    /// wrapper degrades to a fresh start; lossless by construction)
    pub reuse_loss_rate: f64,
    /// hard cap on injected errors + crashes (0 = unlimited); bounds how
    /// much of a workload a fault plan can kill so liveness stays testable
    pub max_faults: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            error_rate: 0.0,
            slow_rate: 0.0,
            slow_ns: 50_000,
            crash_rate: 0.0,
            reuse_loss_rate: 0.0,
            max_faults: 0,
        }
    }
}

impl FaultPlan {
    /// A moderate all-shapes plan for tests: ~5% errors, ~10% slow steps,
    /// ~1% crashes, ~10% lost leases, capped at `max_faults` kills.
    pub fn moderate(seed: u64, max_faults: u64) -> FaultPlan {
        FaultPlan {
            seed,
            error_rate: 0.05,
            slow_rate: 0.10,
            slow_ns: 50_000,
            crash_rate: 0.01,
            reuse_loss_rate: 0.10,
            max_faults,
        }
    }

    /// Does this plan inject anything at all?
    pub fn is_active(&self) -> bool {
        self.error_rate > 0.0
            || self.slow_rate > 0.0
            || self.crash_rate > 0.0
            || self.reuse_loss_rate > 0.0
    }

    /// The same plan with a decorrelated seed — one stream per wrapped
    /// model so slot models, the batcher's verifier, and the stepper's
    /// drafter each draw independent fault sequences.
    pub fn fork(&self, salt: u64) -> FaultPlan {
        let mut p = *self;
        p.seed = Rng::new(self.seed).fork(salt).next_u64();
        p
    }
}

/// Shared fault counters — the observability the engine-fault tests and
/// the sim harness assert against. Cloned handles read one tally.
#[derive(Default)]
pub struct FaultStats {
    /// forwards answered with `Err`
    pub errors: AtomicU64,
    /// forwards that went sticky-broken (panic-equivalent)
    pub crashes: AtomicU64,
    /// slow forwards injected
    pub slow: AtomicU64,
    /// reuse leases dropped on `retain_prefix`/`adopt_pages`
    pub lost_leases: AtomicU64,
    /// accumulated virtual latency, in nanoseconds (fake-clock fuel)
    pub delay_ns: AtomicU64,
}

impl FaultStats {
    /// errors + crashes so far (the `max_faults` ledger).
    pub fn kills(&self) -> u64 {
        self.errors.load(Ordering::Relaxed) + self.crashes.load(Ordering::Relaxed)
    }
}

/// A [`LanguageModel`] that forwards to an inner backend, injecting the
/// faults its [`FaultPlan`] prescribes (module docs). Wrap with
/// [`FaultyModel::wrap`]; read outcomes via [`FaultyModel::stats`].
pub struct FaultyModel {
    inner: Box<dyn LanguageModel>,
    plan: FaultPlan,
    rng: Rng,
    stats: Arc<FaultStats>,
    /// sticky-broken flag: a crash fault poisons every forward until the
    /// next request reseats the model
    broken: bool,
    /// currently routed pooled drafter (docs/ARCHITECTURE.md §17);
    /// forwards routed through drafter `d > 0` draw their fault decisions
    /// from `alt_rngs[d-1]` so each drafter's schedule replays
    /// independently of how often the selection layer plays the others
    drafter: usize,
    /// lazily grown per-drafter fault streams (index `d-1`), forked off
    /// the same seed as the authoritative drafter-0 stream
    alt_rngs: Vec<Rng>,
}

impl FaultyModel {
    /// Wrap `inner` under `plan` (fault stream forked off `plan.seed`).
    pub fn new(inner: Box<dyn LanguageModel>, plan: FaultPlan) -> FaultyModel {
        FaultyModel {
            inner,
            rng: Rng::new(plan.seed ^ 0xFA17),
            plan,
            stats: Arc::new(FaultStats::default()),
            broken: false,
            drafter: 0,
            alt_rngs: Vec::new(),
        }
    }

    /// Like [`FaultyModel::new`], boxed for `SlotPool::from_pairs`.
    pub fn wrap(inner: Box<dyn LanguageModel>, plan: FaultPlan) -> Box<dyn LanguageModel> {
        Box::new(FaultyModel::new(inner, plan))
    }

    /// Handle to this wrapper's fault tally.
    pub fn stats(&self) -> Arc<FaultStats> {
        self.stats.clone()
    }

    /// Are further kills (errors/crashes) allowed under `max_faults`?
    fn kills_left(&self) -> bool {
        self.plan.max_faults == 0 || self.stats.kills() < self.plan.max_faults
    }

    /// Draw one fault decision from the stream owned by the currently
    /// routed drafter. Drafter 0 draws from the authoritative stream
    /// (`self.rng`) so a pool of one is byte-identical to the pre-pool
    /// wrapper; drafter `d > 0` draws from a lazily forked side stream so
    /// its fault schedule replays independently of how often the
    /// selection layer routes through the other drafters.
    fn draw(&mut self, p: f64) -> bool {
        let d = self.drafter;
        if d == 0 {
            return self.rng.bool(p);
        }
        while self.alt_rngs.len() < d {
            let i = self.alt_rngs.len() as u64 + 1;
            self.alt_rngs.push(Rng::new(self.plan.seed ^ 0xFA17).fork(0xD8AF ^ i));
        }
        self.alt_rngs[d - 1].bool(p)
    }

    /// The per-forward fault gate shared by `block`/`block_batch`/
    /// `draft_batch`: slow first (orthogonal to failure), then crash,
    /// then transient error.
    fn forward_gate(&mut self, what: &str) -> anyhow::Result<()> {
        if self.broken {
            anyhow::bail!("injected crash: model is down until reseated");
        }
        if self.draw(self.plan.slow_rate) {
            self.stats.slow.fetch_add(1, Ordering::Relaxed);
            self.stats.delay_ns.fetch_add(self.plan.slow_ns, Ordering::Relaxed);
        }
        if self.kills_left() && self.draw(self.plan.crash_rate) {
            self.broken = true;
            self.stats.crashes.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("injected crash during {what}");
        }
        if self.kills_left() && self.draw(self.plan.error_rate) {
            self.stats.errors.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("injected fault during {what}");
        }
        Ok(())
    }
}

impl LanguageModel for FaultyModel {
    fn name(&self) -> String {
        format!("faulty({})", self.inner.name())
    }

    fn reset(&mut self) {
        self.broken = false;
        self.inner.reset();
    }

    fn begin_request(&mut self, seed: u64, category: &str) {
        self.broken = false;
        self.inner.begin_request(seed, category);
    }

    fn retain_prefix(&mut self, seed: u64, category: &str, keep: usize) -> usize {
        self.broken = false;
        if self.rng.bool(self.plan.reuse_loss_rate) {
            // lost lease: degrade to a fresh start (lossless — only
            // recomputed prefill rows, never wrong tokens)
            self.stats.lost_leases.fetch_add(1, Ordering::Relaxed);
            self.inner.begin_request(seed, category);
            self.inner.reset();
            return 0;
        }
        self.inner.retain_prefix(seed, category, keep)
    }

    fn page_view(&self) -> PageView {
        self.inner.page_view()
    }

    fn adopt_pages(&mut self, seed: u64, category: &str, local: usize, shared: usize) -> usize {
        self.broken = false;
        if self.rng.bool(self.plan.reuse_loss_rate) {
            self.stats.lost_leases.fetch_add(1, Ordering::Relaxed);
            self.inner.begin_request(seed, category);
            self.inner.reset();
            return 0;
        }
        self.inner.adopt_pages(seed, category, local, shared)
    }

    fn block(&mut self, tokens: &[u32], start: usize) -> anyhow::Result<Vec<TokenSignals>> {
        self.forward_gate("block")?;
        self.inner.block(tokens, start)
    }

    fn block_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.forward_gate("block_batch")?;
        self.inner.block_batch(seqs)
    }

    fn draft_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.forward_gate("draft_batch")?;
        self.inner.draft_batch(seqs)
    }

    fn speculate_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        // Speculative forwards draw NO fault randomness: the fault stream
        // is keyed to the authoritative forward sequence so the same plan
        // replays byte-identically whether or not the stepper pipelines.
        // A fault during speculation would be indistinguishable from a
        // discard, so only the sticky crash condition applies.
        if self.broken {
            anyhow::bail!("injected crash: model is down until reseated");
        }
        self.inner.speculate_batch(seqs)
    }

    fn n_drafters(&self) -> usize {
        self.inner.n_drafters()
    }

    fn set_drafter(&mut self, d: usize) {
        // routing is pure bookkeeping: no fault randomness is consumed, so
        // switching drafters never shifts anyone's schedule
        self.drafter = d;
        self.inner.set_drafter(d);
    }

    fn score_drafters(
        &mut self,
        seed: u64,
        category: &str,
        tokens: &[u32],
        start: usize,
    ) -> Vec<f64> {
        // Full-information scoring draws NO fault randomness, exactly like
        // `speculate_batch`: it rides the already-verified tokens and a
        // fault here would be indistinguishable from a discard.
        self.inner.score_drafters(seed, category, tokens, start)
    }

    fn cur(&self) -> usize {
        self.inner.cur()
    }

    fn rollback(&mut self, to: usize) {
        self.inner.rollback(to)
    }

    fn max_seq(&self) -> usize {
        self.inner.max_seq()
    }

    fn cost(&self) -> ModelCost {
        self.inner.cost()
    }

    fn rel_cost(&self) -> f64 {
        self.inner.rel_cost()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::sim::{sim_pair, Scenario, SimModel};

    fn noisy(seed: u64) -> FaultPlan {
        FaultPlan { seed, error_rate: 0.3, crash_rate: 0.1, ..FaultPlan::default() }
    }

    #[test]
    fn inactive_plan_is_transparent() {
        let (_, t) = sim_pair(7, "qa", 0.9);
        let mut plain = SimModel::target(Scenario::new(7, "qa"));
        let mut wrapped = FaultyModel::new(Box::new(t), FaultPlan::default());
        assert!(!FaultPlan::default().is_active());
        let a = plain.block(&[3, 4, 5], 0).unwrap();
        let b = wrapped.block(&[3, 4, 5], 0).unwrap();
        assert_eq!(a, b, "fault-free wrapper must be byte-transparent");
        assert!(wrapped.page_view().adoptive);
    }

    #[test]
    fn faults_are_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let (_, t) = sim_pair(1, "qa", 0.9);
            let mut m = FaultyModel::new(Box::new(t), noisy(seed));
            (0..50)
                .map(|_| {
                    let start = m.cur();
                    let ok = m.block(&[3], start).is_ok();
                    if !ok {
                        m.begin_request(1, "qa"); // reseat after any fault
                        m.reset();
                    }
                    ok
                })
                .collect()
        };
        assert_eq!(run(5), run(5), "same seed ⇒ identical fault sequence");
        assert_ne!(run(5), run(6), "different seeds decorrelate");
        assert!(run(5).iter().any(|&ok| !ok), "faults actually fire");
    }

    #[test]
    fn speculation_never_shifts_the_fault_stream() {
        // The same fault plan must inject the identical fault sequence on
        // the authoritative forwards whether or not speculative forwards
        // are interleaved — the invariant that keeps sim plans replaying
        // byte-identically with pipelining on or off.
        let run = |speculate: bool| -> Vec<bool> {
            let (_, t) = sim_pair(1, "qa", 0.9);
            let mut m = FaultyModel::new(Box::new(t), noisy(5));
            (0..40)
                .map(|_| {
                    if speculate {
                        let item = BatchItem {
                            seq: 0,
                            seed: 1,
                            category: "qa".to_string(),
                            tokens: vec![3],
                            start: m.cur(),
                            drafter: 0,
                        };
                        let _ = m.speculate_batch(&[item]);
                    }
                    let start = m.cur();
                    let ok = m.block(&[3], start).is_ok();
                    if !ok {
                        m.begin_request(1, "qa");
                        m.reset();
                    }
                    ok
                })
                .collect()
        };
        assert_eq!(run(false), run(true), "speculation must not consume fault randomness");
    }

    #[test]
    fn speculation_respects_sticky_crash() {
        let (_, t) = sim_pair(2, "qa", 0.9);
        let plan = FaultPlan { seed: 3, crash_rate: 1.0, ..FaultPlan::default() };
        let mut m = FaultyModel::new(Box::new(t), plan);
        assert!(m.block(&[3], 0).is_err(), "crash fires");
        let item = BatchItem {
            seq: 0,
            seed: 2,
            category: "qa".to_string(),
            tokens: vec![3],
            start: 0,
            drafter: 0,
        };
        assert!(m.speculate_batch(&[item]).is_err(), "broken model can't speculate either");
        assert_eq!(m.stats().crashes.load(Ordering::Relaxed), 1, "no new fault drawn");
    }

    #[test]
    fn per_drafter_fault_streams_are_independent() {
        // Drafter 0's fault schedule must be byte-identical whether or not
        // forwards routed through drafter 1 are interleaved — each pooled
        // drafter owns its own fault stream, so the selection layer's
        // routing choices never shift anyone else's schedule.
        let run = |interleave: bool| -> Vec<bool> {
            let (d, _) = sim_pair(1, "qa", 0.9);
            let mut m = FaultyModel::new(Box::new(d.with_drafters(2)), noisy(5));
            (0..40)
                .map(|_| {
                    if interleave {
                        m.set_drafter(1);
                        let start = m.cur();
                        if m.block(&[3], start).is_err() {
                            m.begin_request(1, "qa");
                            m.reset();
                        }
                    }
                    m.set_drafter(0);
                    let start = m.cur();
                    let ok = m.block(&[3], start).is_ok();
                    if !ok {
                        m.begin_request(1, "qa");
                        m.reset();
                    }
                    ok
                })
                .collect()
        };
        assert_eq!(run(false), run(true), "drafter-1 routing must not shift drafter 0's stream");
        assert!(run(false).iter().any(|&ok| !ok), "faults actually fire");
    }

    #[test]
    fn crash_is_sticky_until_reseated() {
        let (_, t) = sim_pair(2, "qa", 0.9);
        let plan = FaultPlan { seed: 3, crash_rate: 1.0, ..FaultPlan::default() };
        let mut m = FaultyModel::new(Box::new(t), plan);
        assert!(m.block(&[3], 0).is_err(), "crash fires");
        assert!(m.block(&[3], 0).is_err(), "still down: panic-equivalent");
        assert_eq!(m.stats().crashes.load(Ordering::Relaxed), 1, "sticky, not re-counted");
        m.begin_request(2, "qa");
        m.reset();
        // crash_rate 1.0 re-crashes immediately, but the *broken* flag was
        // cleared — the next failure is a fresh crash, proving the reseat
        assert!(m.block(&[3], 0).is_err());
        assert_eq!(m.stats().crashes.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn max_faults_caps_kills_and_reuse_loss_is_lossless() {
        let (_, t) = sim_pair(4, "qa", 0.9);
        let plan = FaultPlan { seed: 9, error_rate: 1.0, max_faults: 2, ..FaultPlan::default() };
        let mut m = FaultyModel::new(Box::new(t), plan);
        assert!(m.block(&[3], 0).is_err());
        assert!(m.block(&[3], 0).is_err());
        // cap reached: forwards succeed from here on
        assert!(m.block(&[3], 0).is_ok());
        assert_eq!(m.stats().errors.load(Ordering::Relaxed), 2);

        // a lost lease reports zero residency and resets the inner cursor —
        // exactly the fresh-start contract the engine already handles
        let (_, t) = sim_pair(4, "qa", 0.9);
        let mut m = FaultyModel::new(
            Box::new(t),
            FaultPlan { seed: 9, reuse_loss_rate: 1.0, ..FaultPlan::default() },
        );
        m.block(&[3, 4, 5], 0).unwrap();
        assert_eq!(m.retain_prefix(4, "qa", 2), 0, "lease lost");
        assert_eq!(m.cur(), 0, "inner model reseated fresh");
        assert_eq!(m.stats().lost_leases.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn slow_steps_accumulate_virtual_delay_only() {
        let (_, t) = sim_pair(5, "qa", 0.9);
        let plan = FaultPlan { seed: 1, slow_rate: 1.0, slow_ns: 1000, ..FaultPlan::default() };
        let mut m = FaultyModel::new(Box::new(t), plan);
        let t0 = std::time::Instant::now();
        for i in 0..10 {
            m.block(&[3], i).unwrap();
        }
        assert_eq!(m.stats().delay_ns.load(Ordering::Relaxed), 10_000);
        assert_eq!(m.stats().slow.load(Ordering::Relaxed), 10);
        assert!(t0.elapsed().as_millis() < 500, "virtual delay never sleeps");
    }
}
