//! Backend-agnostic model interface shared by the PJRT backend (real tiny
//! LMs from artifacts/) and the simulator backend (synthetic correlated
//! streams). The speculative-decoding session (spec/session.rs) is written
//! against this trait only.
//!
//! Two entry points exist for running a forward pass:
//!
//! * [`LanguageModel::block`] — the single-sequence hot path: feed a
//!   contiguous token block at the model's cursor, get one signal row per
//!   token back.
//! * [`LanguageModel::block_batch`] — the cross-session batched path
//!   (docs/ARCHITECTURE.md §4): several *different* sequences' blocks are
//!   coalesced into one target forward. Backends with a native batched
//!   implementation (the simulator, the PJRT batch verifier) override it;
//!   the default loops [`block`](LanguageModel::block) so single-sequence
//!   backends keep working unchanged.
//!
//! The batched path additionally splits into submit/await halves
//! ([`LanguageModel::submit_batch`] → [`PendingBatch::wait`],
//! docs/ARCHITECTURE.md §16) so the continuous stepper can overlap the
//! next micro-round's drafting with an in-flight verify; the default
//! degrades to the blocking call so every existing backend keeps working.

use crate::signals::TokenSignals;

/// A backend's view of its paged-KV capabilities
/// (docs/ARCHITECTURE.md §13), read by the engine's
/// [`SlotPool`](../engine/struct.SlotPool.html) when deciding whether a
/// checkout may adopt *another* slot's resident pages.
///
/// The page table itself (chains, refcounts, copy-on-write) lives in the
/// engine's `PagePool`; what a backend declares here is whether its
/// sequence state is **content-addressed** — i.e. whether position `p`'s
/// KV depends only on the token ids at positions `≤ p` (then mapping a
/// matching prefix computed under a different slot id is exact) — or
/// **slot-resident** (per-slot device worlds that cannot alias, so only
/// same-slot contiguous-cursor reuse is sound).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageView {
    /// can this model adopt a token-matching prefix that a *different*
    /// slot computed? The simulator can (its signal rows are pure
    /// functions of (scenario, position), so validity is token-content
    /// equality, not compute history); PJRT models cannot (per-slot
    /// resident worlds) and fall back to their contiguous cursor.
    pub adoptive: bool,
    /// resident positions (== the cursor for contiguous backends)
    pub resident: usize,
    /// cumulative prompt tokens adopted from shared pages (0 for
    /// non-adoptive backends)
    pub adopted_tokens: u64,
}

/// Cumulative compute counters (the analytic cost model of DESIGN.md §3).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModelCost {
    /// number of block invocations (≈ kernel-launch / dispatch count)
    pub calls: u64,
    /// total token rows processed (≈ FLOPs ∝ rows × params)
    pub rows: u64,
    /// padded rows actually computed (bucket waste included)
    pub padded_rows: u64,
}

/// One sequence's contribution to a batched forward
/// ([`LanguageModel::block_batch`]).
///
/// A `BatchItem` is self-describing: it carries the stable per-sequence
/// key (`seq`, the engine's KV-slot id), the scenario rebind for
/// stateless backends (`seed`/`category`, mirroring
/// [`LanguageModel::begin_request`]), and the contiguous token block
/// (`tokens` at absolute position `start`). The caller — normally the
/// engine's verification batcher (`engine/batcher.rs`) — guarantees the
/// per-sequence contiguity invariant: `start` equals the sequence's
/// committed cursor, exactly as for [`LanguageModel::block`].
#[derive(Clone, Debug)]
pub struct BatchItem {
    /// stable per-sequence key (the engine's KV-slot id); KV-cache
    /// backends key their resident per-sequence state on it
    pub seq: usize,
    /// scenario seed (pure function of the prompt; drives the simulator)
    pub seed: u64,
    /// workload category (drives the simulator's difficulty profile)
    pub category: String,
    /// contiguous token block to feed
    pub tokens: Vec<u32>,
    /// absolute position of `tokens[0]` — must equal the sequence cursor
    pub start: usize,
    /// which pooled draft model proposes this item (docs/ARCHITECTURE.md
    /// §17); always 0 for verify items and single-drafter backends
    pub drafter: usize,
}

/// A batched forward that has been *submitted* but not yet awaited — the
/// result half of the [`LanguageModel::submit_batch`] split
/// (docs/ARCHITECTURE.md §16).
///
/// `PendingBatch` is a concrete struct rather than an associated type so
/// the trait stays object-safe (`Box<dyn LanguageModel>` is how every
/// engine path holds its models). Backends without a truly asynchronous
/// execution path construct it eagerly via [`PendingBatch::ready`] — the
/// forward runs at submit time and `wait` just hands the rows over. That
/// is still the correct *contract*: errors surface at `wait`, and the
/// caller may do unrelated work (speculative pre-drafting) between submit
/// and wait.
pub struct PendingBatch {
    rows: anyhow::Result<Vec<Vec<TokenSignals>>>,
}

impl PendingBatch {
    /// An already-completed batch: `wait` returns `rows` immediately.
    pub fn ready(rows: anyhow::Result<Vec<Vec<TokenSignals>>>) -> PendingBatch {
        PendingBatch { rows }
    }

    /// Block until the forward completes and return its rows (or the
    /// forward's error — failures always surface here, never at submit).
    pub fn wait(self) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.rows
    }
}

/// The model interface the speculative-decoding session loop drives.
///
/// Implementors: `PjrtModel` (artifact-backed tiny LMs), `SimModel`
/// (synthetic correlated streams), `PjrtBatchVerifier` (multi-sequence
/// PJRT verification) and the engine's `BatchedTarget` submit/await
/// handle.
pub trait LanguageModel: Send {
    /// Human-readable backend/model identifier.
    fn name(&self) -> String;

    /// Start a fresh sequence: the write cursor returns to 0. KV contents
    /// need not be cleared — garbage beyond the cursor is never read.
    fn reset(&mut self);

    /// Rebind per-request context before a serving-engine decode. Backends
    /// with per-request state override this (the simulator reseats its
    /// scenario on the request's seed/category); KV-cache backends need
    /// nothing — `generate()` resets the cursor itself.
    fn begin_request(&mut self, _seed: u64, _category: &str) {}

    /// Rebind per-request context while *retaining* the first `keep`
    /// positions of resident sequence state — the cross-request
    /// prefix-reuse entry point (docs/ARCHITECTURE.md §12). Returns how
    /// many positions are actually retained; the cursor ends there, so a
    /// following [`block`](LanguageModel::block) at that offset prefills
    /// only the suffix.
    ///
    /// **Contract.** The caller guarantees the new request's prompt
    /// matches the resident sequence token-for-token over the first
    /// `keep` positions (the engine's `PrefixIndex` routing enforces
    /// this; reuse is deliberate, never accidental). Backends without
    /// retainable per-sequence state use this default — a full reset plus
    /// request rebind, returning 0 — so reuse silently degrades to a
    /// fresh prefill rather than corrupting outputs. `keep = 0` is
    /// exactly the reset-on-checkout default every slot checkout applies
    /// on a cache miss.
    fn retain_prefix(&mut self, seed: u64, category: &str, keep: usize) -> usize {
        let _ = keep;
        self.reset();
        self.begin_request(seed, category);
        0
    }

    /// This backend's paged-KV capability view (docs/ARCHITECTURE.md
    /// §13). The default declares a non-adoptive contiguous-cursor
    /// backend: only same-slot prefix reuse is sound.
    fn page_view(&self) -> PageView {
        PageView { adoptive: false, resident: self.cur(), adopted_tokens: 0 }
    }

    /// Rebind per-request context adopting shared KV pages: `local`
    /// positions of *this slot's own* resident state match the new
    /// prompt (the same guarantee as
    /// [`retain_prefix`](LanguageModel::retain_prefix)), and `shared ≥
    /// local` positions are covered by token-matching pages the engine's
    /// page index mapped in — possibly computed under a different slot.
    /// Returns the positions actually resident afterwards.
    ///
    /// **Contract.** The caller guarantees the prompt matches the shared
    /// pages token-for-token over the first `shared` positions and this
    /// slot's own state over the first `local` positions, with
    /// `shared < prompt_len` (the last prompt token is always re-fed).
    /// Adoptive backends ([`PageView::adoptive`]) take the full `shared`
    /// residency; the default falls back to the same-slot
    /// contiguous-cursor amount — `retain_prefix(seed, category,
    /// local)` — so cross-slot sharing silently degrades to PR-5
    /// slot-affinity reuse rather than corrupting outputs.
    fn adopt_pages(&mut self, seed: u64, category: &str, local: usize, shared: usize) -> usize {
        let _ = shared;
        self.retain_prefix(seed, category, local)
    }

    /// Feed `tokens` at absolute position `start`, which must equal
    /// `cur()` (contiguity invariant). Returns one signal row per token:
    /// row i describes the model's next-token distribution after input
    /// position start+i. Advances `cur` by tokens.len().
    fn block(&mut self, tokens: &[u32], start: usize) -> anyhow::Result<Vec<TokenSignals>>;

    /// Run one forward over several sequences' blocks at once, returning
    /// each item's signal rows in input order (the cross-session batched
    /// verification entry point, docs/ARCHITECTURE.md §4).
    ///
    /// The default implementation processes items one at a time through
    /// [`block`](LanguageModel::block), rolling the cursor back to each
    /// item's `start` first — correct for streams drawn from a *single*
    /// sequence (or a backend whose `begin_request` leaves the cursor in
    /// place), and an explicit contiguity error otherwise. Backends with
    /// true multi-sequence state override it: the simulator computes every
    /// row in one padded pass, and the PJRT batch verifier keeps one
    /// resident world per `BatchItem::seq` and executes shape-bucketed
    /// stacked forwards.
    fn block_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        let mut out = Vec::with_capacity(seqs.len());
        for item in seqs {
            self.rollback(item.start);
            out.push(self.block(&item.tokens, item.start)?);
        }
        Ok(out)
    }

    /// Run one forward over several sequences' *draft* blocks at once —
    /// the continuous-batching engine's drafting entry point
    /// (docs/ARCHITECTURE.md §11). Semantically identical to
    /// [`block_batch`](LanguageModel::block_batch) (each item's rows come
    /// back in input order, byte-identical to feeding the item through
    /// [`block`](LanguageModel::block) on its own slot model), but kept
    /// as a separate path because the call pattern differs: the stepper
    /// issues one `draft_batch` per drafting micro-round — a ragged mix
    /// of long catch-up blocks (prefill rounds) and single-token
    /// continuation blocks — and per-arm draft lengths make successive
    /// batches shrink as sessions stop drafting. Backends pad the ragged
    /// batch to their bucket ladder and account the waste in
    /// [`ModelCost::padded_rows`], which is what the engine's
    /// `engine.step` pad-waste gauge reads.
    ///
    /// The default implementation processes items one at a time through
    /// [`block`](LanguageModel::block), with the same single-sequence
    /// caveat as the default `block_batch`. Backends with true
    /// multi-sequence state override it (the simulator's padded pass,
    /// the PJRT batch executor's resident worlds).
    fn draft_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        let mut out = Vec::with_capacity(seqs.len());
        for item in seqs {
            self.rollback(item.start);
            out.push(self.block(&item.tokens, item.start)?);
        }
        Ok(out)
    }

    /// Submit a batched verification forward without waiting for it — the
    /// submit half of the pipelined verify path (docs/ARCHITECTURE.md
    /// §16). The caller gets a [`PendingBatch`] immediately and may run
    /// other work (the stepper speculatively pre-drafts the next
    /// micro-round) before calling [`PendingBatch::wait`].
    ///
    /// The default degrades to the existing blocking
    /// [`block_batch`](LanguageModel::block_batch): the forward runs
    /// eagerly at submit time and `wait` returns the stored result.
    /// Backends keep working unchanged — `FaultyModel`, the PJRT paths
    /// and `BatchedTarget` all inherit this default — because the
    /// observable contract (row values, error surfacing at `wait`, cursor
    /// state after the call) is identical; only the *caller's* freedom to
    /// overlap work in between is new.
    fn submit_batch(&mut self, seqs: &[BatchItem]) -> PendingBatch {
        PendingBatch::ready(self.block_batch(seqs))
    }

    /// Run a *speculative* draft forward — rows the caller may throw away
    /// (docs/ARCHITECTURE.md §16). Semantically identical to
    /// [`draft_batch`](LanguageModel::draft_batch), and the default simply
    /// delegates to it; the separate entry point exists for fault
    /// determinism. Fault-injecting wrappers key their deterministic
    /// fault streams to the *authoritative* forward sequence, so a
    /// speculative forward must not consume fault randomness — otherwise
    /// enabling pipelining would shift every subsequent fault and break
    /// the byte-identical replay contract. `FaultyModel` overrides this
    /// to pass through without drawing from its RNG (a fault during
    /// speculation is indistinguishable from a discard anyway).
    fn speculate_batch(&mut self, seqs: &[BatchItem]) -> anyhow::Result<Vec<Vec<TokenSignals>>> {
        self.draft_batch(seqs)
    }

    /// Number of tokens processed as inputs so far (== next input position).
    fn cur(&self) -> usize;

    /// Roll the cursor back to `to` (no-op if already &le; to). KV beyond
    /// the cursor becomes dead and will be overwritten on re-feed.
    fn rollback(&mut self, to: usize);

    /// Maximum sequence length the KV cache supports.
    fn max_seq(&self) -> usize;

    /// Cumulative cost counters since construction.
    fn cost(&self) -> ModelCost;

    /// Relative cost of one token row vs target-base (for the analytic
    /// cost model; ≈ param ratio).
    fn rel_cost(&self) -> f64 {
        1.0
    }

    /// Number of pooled draft models this backend hosts
    /// (docs/ARCHITECTURE.md §17). Verifiers and single-drafter backends
    /// report 1, which keeps the whole drafter-selection layer a no-op —
    /// a pool of one is byte-identical to the pre-pool engine.
    fn n_drafters(&self) -> usize {
        1
    }

    /// Route subsequent single-sequence draft forwards
    /// ([`block`](LanguageModel::block)) through pooled drafter `d`.
    /// Batched paths carry the drafter per item ([`BatchItem::drafter`])
    /// instead. Backends without a pool ignore it.
    fn set_drafter(&mut self, _d: usize) {}

    /// Full-information drafter scoring (Not-a-Bandit, docs §17): given
    /// the `tokens` a verify round just committed at absolute position
    /// `start`, return each pooled drafter's agreement fraction — the
    /// share of those tokens drafter `d` *would have proposed* — in
    /// `[0, 1]`, one entry per drafter.
    ///
    /// **Contract.** Scoring is pure bookkeeping over already-known
    /// rows: it must not move the cursor, must not count model cost, and
    /// must not consume fault randomness (fault wrappers pass through
    /// without drawing from their RNG, exactly like
    /// [`speculate_batch`](LanguageModel::speculate_batch)) — otherwise
    /// enabling a second drafter would shift every replayed fault
    /// schedule. The default credits every drafter fully, which makes
    /// the selection layer inert for pool-of-one backends.
    fn score_drafters(
        &mut self,
        _seed: u64,
        _category: &str,
        tokens: &[u32],
        _start: usize,
    ) -> Vec<f64> {
        let _ = tokens;
        vec![1.0; self.n_drafters()]
    }
}
