//! Backend-agnostic model interface shared by the PJRT backend (real tiny
//! LMs from artifacts/) and the simulator backend (synthetic correlated
//! streams). The speculative-decoding session (spec/session.rs) is written
//! against this trait only.

use crate::signals::TokenSignals;

/// Cumulative compute counters (the analytic cost model of DESIGN.md §3).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ModelCost {
    /// number of block invocations (≈ kernel-launch / dispatch count)
    pub calls: u64,
    /// total token rows processed (≈ FLOPs ∝ rows × params)
    pub rows: u64,
    /// padded rows actually computed (bucket waste included)
    pub padded_rows: u64,
}

pub trait LanguageModel: Send {
    /// Human-readable backend/model identifier.
    fn name(&self) -> String;

    /// Start a fresh sequence: the write cursor returns to 0. KV contents
    /// need not be cleared — garbage beyond the cursor is never read.
    fn reset(&mut self);

    /// Rebind per-request context before a serving-engine decode. Backends
    /// with per-request state override this (the simulator reseats its
    /// scenario on the request's seed/category); KV-cache backends need
    /// nothing — `generate()` resets the cursor itself.
    fn begin_request(&mut self, _seed: u64, _category: &str) {}

    /// Feed `tokens` at absolute position `start`, which must equal
    /// `cur()` (contiguity invariant). Returns one signal row per token:
    /// row i describes the model's next-token distribution after input
    /// position start+i. Advances `cur` by tokens.len().
    fn block(&mut self, tokens: &[u32], start: usize) -> anyhow::Result<Vec<TokenSignals>>;

    /// Number of tokens processed as inputs so far (== next input position).
    fn cur(&self) -> usize;

    /// Roll the cursor back to `to` (no-op if already &le; to). KV beyond
    /// the cursor becomes dead and will be overwritten on re-feed.
    fn rollback(&mut self, to: usize);

    /// Maximum sequence length the KV cache supports.
    fn max_seq(&self) -> usize;

    /// Cumulative cost counters since construction.
    fn cost(&self) -> ModelCost;

    /// Relative cost of one token row vs target-base (for the analytic
    /// cost model; ≈ param ratio).
    fn rel_cost(&self) -> f64 {
        1.0
    }
}
