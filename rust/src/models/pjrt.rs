//! PJRT-backed model — the production backend. Executes the AOT HLO
//! artifacts with a device-resident world buffer:
//!
//!   host                         device
//!   ----                         ------
//!   tokens[K], start  ──────▶   block_K(wflat, world, tokens, start)
//!   signals [n×8]     ◀──────   world' (new buffer; fed back next call)
//!
//! Weights are uploaded once per model and shared (Arc) across serving
//! slots; executables are compiled lazily per shape bucket and shared too.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::models::manifest::{Manifest, ModelSpec};
use crate::models::traits::{LanguageModel, ModelCost};
use crate::runtime::{ExecutableCache, Runtime, SendWrap};
use crate::signals::{TokenSignals, SIG_WIDTH};

/// Per-model immutable assets shared by all instances (serving slots).
pub struct ModelAssets {
    pub runtime: Runtime,
    pub spec: ModelSpec,
    pub weights: SendWrap<xla::PjRtBuffer>,
    pub exes: ExecutableCache,
    /// per-bucket signal extractors (world -> [k*8]); PJRT CPU cannot
    /// offset-read device buffers, so the out-region is sliced on device
    pub extractors: ExecutableCache,
    /// token-row cost relative to target-base (analytic cost model)
    pub rel_cost: f64,
}

// SAFETY: PJRT CPU objects are used from one engine thread at a time; the
// Rc-based client clone count is only mutated while a single thread owns the
// assets (see runtime::SendWrap).
unsafe impl Send for ModelAssets {}
unsafe impl Sync for ModelAssets {}
unsafe impl Send for PjrtModel {}

impl ModelAssets {
    pub fn load(runtime: &Runtime, manifest: &Manifest, name: &str) -> Result<Arc<ModelAssets>> {
        let spec = manifest.model(name)?.clone();
        let host = manifest.load_weights(&spec)?;
        let weights = runtime
            .f32_to_device(&host, &[spec.param_count])
            .with_context(|| format!("uploading weights for {name}"))?;
        let ref_params = manifest
            .model("target-base")
            .map(|m| m.param_count)
            .unwrap_or(spec.param_count);
        let exes = ExecutableCache::new(runtime.clone(), spec.hlo_files.clone());
        let extractors = ExecutableCache::new(runtime.clone(), spec.extract_files.clone());
        Ok(Arc::new(ModelAssets {
            runtime: runtime.clone(),
            spec,
            weights: SendWrap(weights),
            exes,
            extractors,
            rel_cost: spec_rel_cost(&host, ref_params),
        }))
    }
}

fn spec_rel_cost(host: &[f32], ref_params: usize) -> f64 {
    host.len() as f64 / ref_params.max(1) as f64
}

/// A stateful model instance (one per active sequence slot).
pub struct PjrtModel {
    assets: Arc<ModelAssets>,
    world: SendWrap<xla::PjRtBuffer>,
    cur: usize,
    cost: ModelCost,
    sig_host: Vec<f32>,
}

impl PjrtModel {
    pub fn new(assets: Arc<ModelAssets>) -> Result<PjrtModel> {
        let spec = &assets.spec;
        let zeros = vec![0.0f32; spec.world_elems];
        let world = assets.runtime.f32_to_device(&zeros, &[spec.world_elems])?;
        Ok(PjrtModel {
            sig_host: vec![0.0; spec.out_elems],
            world: SendWrap(world),
            assets,
            cur: 0,
            cost: ModelCost::default(),
        })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.assets.spec
    }

    pub fn assets(&self) -> &Arc<ModelAssets> {
        &self.assets
    }

    /// Pre-compile the buckets the serving hot path uses.
    pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
        self.assets.exes.warmup(buckets)
    }
}

impl LanguageModel for PjrtModel {
    fn name(&self) -> String {
        format!("pjrt:{}", self.assets.spec.name)
    }

    fn reset(&mut self) {
        // KV garbage beyond the cursor is never read (contiguity protocol),
        // so resetting is O(1): no device writes needed.
        self.cur = 0;
    }

    fn block(&mut self, tokens: &[u32], start: usize) -> Result<Vec<TokenSignals>> {
        anyhow::ensure!(start == self.cur, "non-contiguous block: start {start} cur {}", self.cur);
        anyhow::ensure!(!tokens.is_empty(), "empty block");
        let n = tokens.len();
        let spec = &self.assets.spec;
        anyhow::ensure!(start + n <= spec.max_seq, "KV overflow: {}+{n} > {}", start, spec.max_seq);

        let k = self.assets.exes.bucket_for(n)?;
        let exe = self.assets.exes.get(k)?;

        // stage tokens (padded to the bucket) and the start scalar
        let mut padded = vec![0i32; k];
        for (dst, &t) in padded.iter_mut().zip(tokens) {
            *dst = t as i32;
        }
        let toks_buf = self.assets.runtime.i32_to_device(&padded, &[k])?;
        let start_buf = self.assets.runtime.scalar_i32(start as i32)?;

        let mut result = exe
            .0
            .execute_b(&[&self.assets.weights.0, &self.world.0, &toks_buf, &start_buf])
            .with_context(|| format!("executing {} block{k}", spec.name))?;
        let new_world = result
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| anyhow::anyhow!("no output buffer"))?;
        self.world = SendWrap(new_world);

        // read back only the signal rows: slice on device (extractor for
        // the smallest bucket >= n), then copy the tiny result to host
        let ek = self.assets.extractors.bucket_for(n)?;
        let ext = self.assets.extractors.get(ek)?;
        let mut eres = ext
            .0
            .execute_b(&[&self.world.0])
            .context("extracting signal out-region")?;
        let sig_buf = eres
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| anyhow::anyhow!("no extractor output"))?;
        let lit = sig_buf.to_literal_sync()?;
        let vals: Vec<f32> = lit.to_vec()?;
        let want = n * SIG_WIDTH;
        self.sig_host[..want].copy_from_slice(&vals[..want]);

        self.cur = start + n;
        self.cost.calls += 1;
        self.cost.rows += n as u64;
        self.cost.padded_rows += k as u64;
        Ok(TokenSignals::parse_rows(&self.sig_host, n))
    }

    fn cur(&self) -> usize {
        self.cur
    }

    fn rollback(&mut self, to: usize) {
        self.cur = self.cur.min(to);
    }

    fn max_seq(&self) -> usize {
        self.assets.spec.max_seq
    }

    fn cost(&self) -> ModelCost {
        self.cost
    }

    fn rel_cost(&self) -> f64 {
        self.assets.rel_cost
    }
}
