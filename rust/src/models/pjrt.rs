//! PJRT-backed model — the production backend. Executes the AOT HLO
//! artifacts with a device-resident world buffer:
//!
//!   host                         device
//!   ----                         ------
//!   tokens[K], start  ──────▶   block_K(wflat, world, tokens, start)
//!   signals [n×8]     ◀──────   world' (new buffer; fed back next call)
//!
//! Weights are uploaded once per model and shared (Arc) across serving
//! slots; executables are compiled lazily per shape bucket and shared too.
//!
//! Two model types live here:
//!
//! * [`PjrtModel`] — one resident sequence (one world buffer), the
//!   single-sequence hot path.
//! * [`PjrtBatchVerifier`] — the cross-session batched verification path
//!   (docs/ARCHITECTURE.md §4): one resident world *per engine slot*,
//!   fed through `block_batch`. When the manifest ships batched
//!   executables (`hlo_batch`), whole batches run as one stacked forward
//!   padded to the manifest's batch buckets; otherwise it degrades to
//!   per-sequence forwards that still amortize weight residency.
//!
//! Both types inherit the trait's blocking `submit_batch` /
//! `speculate_batch` defaults (docs/ARCHITECTURE.md §16): under
//! `--pipeline` the stepper's pre-draft still runs correctly — it just
//! overlaps nothing, because the default `submit_batch` completes the
//! forward eagerly. Genuine overlap needs an override that returns a
//! `PendingBatch` wrapping an in-flight `execute_b` dispatch (PJRT
//! execution is async-capable; the synchronous `to_literal_sync`
//! readback is the part to defer into `wait`), which slots in here
//! without touching the stepper.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::models::manifest::{Manifest, ModelSpec};
use crate::models::traits::{BatchItem, LanguageModel, ModelCost};
use crate::runtime::{ExecutableCache, Runtime, SendWrap};
use crate::signals::{TokenSignals, SIG_WIDTH};

/// Per-model immutable assets shared by all instances (serving slots).
pub struct ModelAssets {
    /// PJRT client handle
    pub runtime: Runtime,
    /// manifest geometry for this model
    pub spec: ModelSpec,
    /// device-resident flat weight buffer, shared by every instance
    pub weights: SendWrap<xla::PjRtBuffer>,
    /// per-bucket single-sequence block executables
    pub exes: ExecutableCache,
    /// per-bucket signal extractors (world -> [k*8]); PJRT CPU cannot
    /// offset-read device buffers, so the out-region is sliced on device
    pub extractors: ExecutableCache,
    /// batched block executables, one cache per batch bucket (empty when
    /// the artifact set ships none — see `ModelSpec::batch_files`)
    pub batch_exes: HashMap<usize, ExecutableCache>,
    /// token-row cost relative to target-base (analytic cost model)
    pub rel_cost: f64,
}

// SAFETY: PJRT CPU objects are used from one engine thread at a time; the
// Rc-based client clone count is only mutated while a single thread owns the
// assets (see runtime::SendWrap).
unsafe impl Send for ModelAssets {}
unsafe impl Sync for ModelAssets {}
unsafe impl Send for PjrtModel {}

impl ModelAssets {
    /// Load one model's weights onto the device and index its executables.
    pub fn load(runtime: &Runtime, manifest: &Manifest, name: &str) -> Result<Arc<ModelAssets>> {
        let spec = manifest.model(name)?.clone();
        let host = manifest.load_weights(&spec)?;
        let weights = runtime
            .f32_to_device(&host, &[spec.param_count])
            .with_context(|| format!("uploading weights for {name}"))?;
        let ref_params = manifest
            .model("target-base")
            .map(|m| m.param_count)
            .unwrap_or(spec.param_count);
        let exes = ExecutableCache::new(runtime.clone(), spec.hlo_files.clone());
        let extractors = ExecutableCache::new(runtime.clone(), spec.extract_files.clone());
        let mut batch_exes = HashMap::new();
        for (&b, files) in &spec.batch_files {
            batch_exes.insert(b, ExecutableCache::new(runtime.clone(), files.clone()));
        }
        Ok(Arc::new(ModelAssets {
            runtime: runtime.clone(),
            spec,
            weights: SendWrap(weights),
            exes,
            extractors,
            batch_exes,
            rel_cost: spec_rel_cost(&host, ref_params),
        }))
    }
}

fn spec_rel_cost(host: &[f32], ref_params: usize) -> f64 {
    host.len() as f64 / ref_params.max(1) as f64
}

/// A stateful model instance (one per active sequence slot).
///
/// The device world buffer is allocated lazily on the first forward, so
/// an instance that never runs — e.g. a slot target idling while the
/// verification batcher owns all target forwards — costs no device
/// memory beyond the struct.
pub struct PjrtModel {
    assets: Arc<ModelAssets>,
    world: Option<SendWrap<xla::PjRtBuffer>>,
    cur: usize,
    cost: ModelCost,
    sig_host: Vec<f32>,
}

impl PjrtModel {
    /// A fresh instance over shared assets (world buffer not yet
    /// allocated).
    pub fn new(assets: Arc<ModelAssets>) -> Result<PjrtModel> {
        Ok(PjrtModel {
            sig_host: vec![0.0; assets.spec.out_elems],
            world: None,
            assets,
            cur: 0,
            cost: ModelCost::default(),
        })
    }

    /// Allocate the zeroed device world on first use.
    pub(crate) fn ensure_world(&mut self) -> Result<()> {
        if self.world.is_none() {
            let spec = &self.assets.spec;
            let zeros = vec![0.0f32; spec.world_elems];
            let world = self.assets.runtime.f32_to_device(&zeros, &[spec.world_elems])?;
            self.world = Some(SendWrap(world));
        }
        Ok(())
    }

    /// Manifest geometry of this model.
    pub fn spec(&self) -> &ModelSpec {
        &self.assets.spec
    }

    /// The shared assets this instance executes against.
    pub fn assets(&self) -> &Arc<ModelAssets> {
        &self.assets
    }

    /// Pre-compile the buckets the serving hot path uses.
    pub fn warmup(&self, buckets: &[usize]) -> Result<()> {
        self.assets.exes.warmup(buckets)
    }

    /// Current world buffer (a stacked batched forward reads it as one
    /// input lane). Callers must have run [`PjrtModel::ensure_world`].
    pub(crate) fn world_ref(&self) -> &xla::PjRtBuffer {
        &self.world.as_ref().expect("world allocated (ensure_world ran)").0
    }

    /// Install the world buffer a batched forward produced for this lane
    /// and advance the cursor to `cur`.
    pub(crate) fn adopt_world(&mut self, world: xla::PjRtBuffer, cur: usize) {
        self.world = Some(SendWrap(world));
        self.cur = cur;
    }

    /// Read the first `n` signal rows out of the current world via the
    /// on-device extractor (shared by `block` and the batched path).
    pub(crate) fn extract_signals(&mut self, n: usize) -> Result<Vec<TokenSignals>> {
        let ek = self.assets.extractors.bucket_for(n)?;
        let ext = self.assets.extractors.get(ek)?;
        let mut eres = ext
            .0
            .execute_b(&[self.world_ref()])
            .context("extracting signal out-region")?;
        let sig_buf = eres
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| anyhow::anyhow!("no extractor output"))?;
        let lit = sig_buf.to_literal_sync()?;
        let vals: Vec<f32> = lit.to_vec()?;
        let want = n * SIG_WIDTH;
        self.sig_host[..want].copy_from_slice(&vals[..want]);
        Ok(TokenSignals::parse_rows(&self.sig_host, n))
    }
}

impl LanguageModel for PjrtModel {
    fn name(&self) -> String {
        format!("pjrt:{}", self.assets.spec.name)
    }

    fn reset(&mut self) {
        // KV garbage beyond the cursor is never read (contiguity protocol),
        // so resetting is O(1): no device writes needed.
        self.cur = 0;
    }

    /// Prefix reuse on PJRT (docs/ARCHITECTURE.md §12) — the
    /// resident-world cursor contract: the device world buffer holds KV
    /// for every position `< cur`, computed from exactly the token ids
    /// this instance was fed, and positions `≥ cur` are dead (rewritten
    /// on the next feed). Retaining is therefore a pure cursor move:
    /// roll back to `min(cur, keep)` and the world's live region *is*
    /// the new request's prompt-prefix KV — provided the caller's `keep`
    /// covers only token-matched positions, which the engine's
    /// `PrefixIndex` routing guarantees. A never-run instance (no world
    /// allocated) has `cur == 0` and correctly retains nothing.
    fn retain_prefix(&mut self, _seed: u64, _category: &str, keep: usize) -> usize {
        self.cur = self.cur.min(keep);
        self.cur
    }

    /// Paged-KV capability (docs/ARCHITECTURE.md §13): **non-adoptive**.
    /// A PJRT world is one opaque device buffer per model instance —
    /// position `p`'s KV physically lives in *this* instance's buffer
    /// and cannot alias a page another slot's instance computed, so the
    /// engine's page index never offers a PJRT slot a cross-slot hit.
    /// The pool's page bookkeeping still tracks residency (the gauges
    /// describe what a paged device layout *would* hold), but reuse
    /// falls back to the same-slot contiguous-cursor path above:
    /// `adopt_pages`'s default ignores `shared` and retains `local`.
    fn page_view(&self) -> crate::models::traits::PageView {
        crate::models::traits::PageView {
            adoptive: false,
            resident: self.cur,
            adopted_tokens: 0,
        }
    }

    fn block(&mut self, tokens: &[u32], start: usize) -> Result<Vec<TokenSignals>> {
        anyhow::ensure!(start == self.cur, "non-contiguous block: start {start} cur {}", self.cur);
        anyhow::ensure!(!tokens.is_empty(), "empty block");
        let n = tokens.len();
        let spec = &self.assets.spec;
        anyhow::ensure!(start + n <= spec.max_seq, "KV overflow: {}+{n} > {}", start, spec.max_seq);

        let k = self.assets.exes.bucket_for(n)?;
        let exe = self.assets.exes.get(k)?;
        self.ensure_world()?;

        // stage tokens (padded to the bucket) and the start scalar
        let mut padded = vec![0i32; k];
        for (dst, &t) in padded.iter_mut().zip(tokens) {
            *dst = t as i32;
        }
        let toks_buf = self.assets.runtime.i32_to_device(&padded, &[k])?;
        let start_buf = self.assets.runtime.scalar_i32(start as i32)?;

        let mut result = exe
            .0
            .execute_b(&[&self.assets.weights.0, self.world_ref(), &toks_buf, &start_buf])
            .with_context(|| format!("executing {} block{k}", spec.name))?;
        let new_world = result
            .pop()
            .and_then(|mut r| if r.is_empty() { None } else { Some(r.remove(0)) })
            .ok_or_else(|| anyhow::anyhow!("no output buffer"))?;
        self.world = Some(SendWrap(new_world));

        self.cur = start + n;
        self.cost.calls += 1;
        self.cost.rows += n as u64;
        self.cost.padded_rows += k as u64;
        self.extract_signals(n)
    }

    fn cur(&self) -> usize {
        self.cur
    }

    fn rollback(&mut self, to: usize) {
        self.cur = self.cur.min(to);
    }

    fn max_seq(&self) -> usize {
        self.assets.spec.max_seq
    }

    fn cost(&self) -> ModelCost {
        self.cost
    }

    fn rel_cost(&self) -> f64 {
        self.assets.rel_cost
    }
}

/// Multi-sequence PJRT verifier for the engine's verification batcher
/// (docs/ARCHITECTURE.md §4).
///
/// Keeps one resident [`PjrtModel`] per engine slot (`BatchItem::seq`),
/// lazily created, so every sequence's KV world survives across batches
/// exactly as a dedicated slot target would. `block_batch` prefers one
/// *stacked* forward over a manifest batch bucket
/// (`weights, world×B, tokens[B*K], starts[B]` — pad lanes re-execute
/// lane 0 and are discarded); when the artifact set ships no batched
/// executables it falls back to per-sequence forwards, which still
/// benefit from batching at the engine level (one dispatcher wake per
/// batch instead of per session).
pub struct PjrtBatchVerifier {
    assets: Arc<ModelAssets>,
    seqs: HashMap<usize, PjrtModel>,
    /// cost of stacked batched forwards; per-sequence fallback forwards
    /// are accounted inside the per-sequence models
    cost: ModelCost,
}

impl PjrtBatchVerifier {
    /// A verifier with no resident sequences yet.
    pub fn new(assets: Arc<ModelAssets>) -> PjrtBatchVerifier {
        PjrtBatchVerifier { assets, seqs: HashMap::new(), cost: ModelCost::default() }
    }

    /// Number of resident per-sequence worlds.
    pub fn resident_seqs(&self) -> usize {
        self.seqs.len()
    }

    fn ensure_seq(&mut self, id: usize) -> Result<()> {
        if !self.seqs.contains_key(&id) {
            self.seqs.insert(id, PjrtModel::new(self.assets.clone())?);
        }
        Ok(())
    }

    /// Roll every item's resident world to its start and check the
    /// per-sequence contiguity invariant. This `ensure` is also the
    /// prefix-reuse guard (docs/ARCHITECTURE.md §12): a cache-hit
    /// session's first block arrives with `start = reuse > 0`, which is
    /// only reachable if this slot's resident world already covers
    /// `reuse` positions — a slot whose resident state was lost (fresh
    /// verifier, cleared seq) fails loudly here instead of silently
    /// recomputing against garbage KV.
    fn align(&mut self, items: &[BatchItem]) -> Result<()> {
        for it in items {
            self.ensure_seq(it.seq)?;
            let m = self.seqs.get_mut(&it.seq).expect("just ensured");
            m.ensure_world()?;
            m.begin_request(it.seed, &it.category);
            m.rollback(it.start);
            anyhow::ensure!(
                m.cur() == it.start,
                "non-contiguous batch item for seq {}: start {} cur {}",
                it.seq,
                it.start,
                m.cur()
            );
            anyhow::ensure!(
                it.start + it.tokens.len() <= self.assets.spec.max_seq,
                "KV overflow in batch: seq {} {}+{} > {}",
                it.seq,
                it.start,
                it.tokens.len(),
                self.assets.spec.max_seq
            );
        }
        Ok(())
    }

    /// One stacked forward over a manifest batch bucket, or `None` when no
    /// batched executable covers this batch shape.
    fn try_stacked(&mut self, items: &[BatchItem]) -> Result<Option<Vec<Vec<TokenSignals>>>> {
        if items.len() < 2 || self.assets.batch_exes.is_empty() {
            return Ok(None);
        }
        let assets = self.assets.clone();
        // the manifest's batch ladder is authoritative: an executable
        // outside it (or a ladder entry with no executable) is never used
        let Some(bb) = assets
            .spec
            .batch_ladder
            .iter()
            .copied()
            .filter(|b| *b >= items.len() && assets.batch_exes.contains_key(b))
            .min()
        else {
            return Ok(None);
        };
        let cache = &assets.batch_exes[&bb];
        let kmax = items.iter().map(|it| it.tokens.len()).max().unwrap_or(0);
        let Ok(kb) = cache.bucket_for(kmax) else {
            return Ok(None);
        };
        let exe = cache.get(kb)?;

        // stage tokens [bb*kb] and starts [bb]; pad lanes replay lane 0 at
        // start 0 and their outputs are discarded
        let mut padded = vec![0i32; bb * kb];
        let mut starts = vec![0i32; bb];
        for (lane, it) in items.iter().enumerate() {
            for (dst, &t) in padded[lane * kb..(lane + 1) * kb].iter_mut().zip(&it.tokens) {
                *dst = t as i32;
            }
            starts[lane] = it.start as i32;
        }
        let toks_buf = assets.runtime.i32_to_device(&padded, &[bb * kb])?;
        let starts_buf = assets.runtime.i32_to_device(&starts, &[bb])?;

        let mut new_worlds: Vec<xla::PjRtBuffer> = {
            let first = &self.seqs[&items[0].seq];
            let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(bb + 3);
            args.push(&assets.weights.0);
            for it in items {
                args.push(self.seqs[&it.seq].world_ref());
            }
            for _ in items.len()..bb {
                args.push(first.world_ref());
            }
            args.push(&toks_buf);
            args.push(&starts_buf);
            let mut result = exe
                .0
                .execute_b(&args)
                .with_context(|| format!("executing {} batch{bb}x{kb}", assets.spec.name))?;
            result.pop().ok_or_else(|| anyhow::anyhow!("no batched output buffers"))?
        };
        anyhow::ensure!(
            new_worlds.len() >= items.len(),
            "batched executable returned {} worlds for {} lanes",
            new_worlds.len(),
            items.len()
        );
        new_worlds.truncate(items.len());

        self.cost.calls += 1;
        self.cost.rows += items.iter().map(|it| it.tokens.len() as u64).sum::<u64>();
        self.cost.padded_rows += (bb * kb) as u64;

        let mut rows = Vec::with_capacity(items.len());
        for (it, world) in items.iter().zip(new_worlds) {
            let m = self.seqs.get_mut(&it.seq).expect("aligned above");
            m.adopt_world(world, it.start + it.tokens.len());
            rows.push(m.extract_signals(it.tokens.len())?);
        }
        Ok(Some(rows))
    }
}

impl LanguageModel for PjrtBatchVerifier {
    fn name(&self) -> String {
        format!("pjrt-batch:{}", self.assets.spec.name)
    }

    fn reset(&mut self) {
        // drop every resident sequence world (fresh engine)
        self.seqs.clear();
    }

    fn block(&mut self, _tokens: &[u32], _start: usize) -> Result<Vec<TokenSignals>> {
        anyhow::bail!("PjrtBatchVerifier is batch-only: use block_batch")
    }

    fn block_batch(&mut self, items: &[BatchItem]) -> Result<Vec<Vec<TokenSignals>>> {
        anyhow::ensure!(!items.is_empty(), "empty batch");
        for it in items {
            anyhow::ensure!(!it.tokens.is_empty(), "empty block in batch (seq {})", it.seq);
        }
        self.align(items)?;
        if let Some(rows) = self.try_stacked(items)? {
            return Ok(rows);
        }
        // fallback: per-sequence forwards through the resident models
        let mut out = Vec::with_capacity(items.len());
        for it in items {
            let m = self.seqs.get_mut(&it.seq).expect("aligned above");
            out.push(m.block(&it.tokens, it.start)?);
        }
        Ok(out)
    }

    /// Native batched drafting (docs/ARCHITECTURE.md §11): this type is
    /// a generic multi-sequence executor — resident world per slot id,
    /// stacked forwards when the manifest ships batched executables — so
    /// the continuous engine instantiates it over the *draft* assets and
    /// drives each drafting micro-round through the same batched path as
    /// verification.
    fn draft_batch(&mut self, items: &[BatchItem]) -> Result<Vec<Vec<TokenSignals>>> {
        self.block_batch(items)
    }

    fn cur(&self) -> usize {
        0
    }

    fn rollback(&mut self, _to: usize) {}

    fn max_seq(&self) -> usize {
        self.assets.spec.max_seq
    }

    fn cost(&self) -> ModelCost {
        let mut c = self.cost;
        for m in self.seqs.values() {
            let mc = m.cost();
            c.calls += mc.calls;
            c.rows += mc.rows;
            c.padded_rows += mc.padded_rows;
        }
        c
    }

    fn rel_cost(&self) -> f64 {
        self.assets.rel_cost
    }
}
