//! SpecBench-analog sweep over a configurable method list — the
//! "kick the tires" version of Table 5 with per-category breakdown.
//!
//!   cargo run --release --offline --example specbench_sweep -- \
//!       [--pair pair-a] [--methods static-6,svip,seq-ucb1] [--per-cat 2]
//!       [--backend pjrt|sim]

use anyhow::Result;

use tapout::harness::{load_suite, run_method, sim_suite, Backend};
use tapout::models::Manifest;
use tapout::runtime::Runtime;
use tapout::spec::MethodSpec;
use tapout::util::cli::Args;
use tapout::util::table::{fmt, Table};

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let pair = args.str("pair", "pair-a");
    let per_cat = args.usize("per-cat", 2);
    let method_names = args.str("methods", "static-6,svip,max-conf,seq-ucb1,token-ucb1");
    let use_sim = args.str("backend", "pjrt") == "sim";

    let (backend, items) = if use_sim {
        (Backend::Sim { quality: 0.9, rel_cost: 1.0 / 16.0 }, sim_suite("specbench", per_cat * 4, 96))
    } else {
        let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
        let runtime = Runtime::cpu()?;
        let items = load_suite(&manifest, "specbench", per_cat * 13)?;
        (Backend::pjrt(&manifest, &runtime, &pair)?, items)
    };

    println!("sweep: pair={pair} over {} prompts", items.len());
    let mut results = Vec::new();
    for name in method_names.split(',') {
        let m = MethodSpec::parse(name.trim(), "artifacts").map_err(|e| anyhow::anyhow!(e))?;
        eprintln!("  running {} ...", m.label());
        results.push(run_method(&backend, &items, &m, 128, false)?);
    }

    let base = &results[0];
    let mut t = Table::new(&["Method", "m", "%", "s (wall)", "s (cost)"]);
    for r in &results {
        let tot = r.total();
        t.row(vec![
            r.method.clone(),
            fmt(tot.mean_accepted(), 2),
            fmt(tot.acceptance_rate(), 2),
            fmt(r.speedup_vs(base), 2),
            fmt(r.cost_speedup_vs(base), 2),
        ]);
    }
    println!("\n{}", t.render());

    // per-category winners
    let mut cats: Vec<String> = base.per_category.keys().cloned().collect();
    cats.sort();
    let mut t2 = Table::new(&["Category", "best method", "s"]);
    for c in &cats {
        let (mut bi, mut bs) = (0, f64::MIN);
        for (i, r) in results.iter().enumerate() {
            let s = r.speedup_vs_cat(base, c);
            if s > bs {
                bs = s;
                bi = i;
            }
        }
        t2.row(vec![c.clone(), results[bi].method.clone(), fmt(bs, 2)]);
    }
    println!("{}", t2.render());
    Ok(())
}
