//! End-to-end serving driver (the DESIGN.md validation run): boots the full
//! engine on the real pair-a artifacts, replays a Poisson arrival stream of
//! TinyBench prompts through the scheduler + KV slot pool + TapOut
//! controller, and reports latency/throughput percentiles.
//!
//!   cargo run --release --offline --example serve_batch -- \
//!       [--requests N] [--rate R] [--method seq-ucb1] [--sched fcfs|sjf]
//!
//! The printed report is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use tapout::engine::{Engine, EngineConfig, Policy, Request};
use tapout::harness::{load_suite, poisson_arrivals};
use tapout::models::Manifest;
use tapout::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let n = args.usize("requests", 24);
    let rate = args.f64("rate", 1.2); // req/s
    let method = args.str("method", "seq-ucb1");
    let sched = args.str("sched", "fcfs");

    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let items = load_suite(&manifest, "mtbench", n)?;

    let slots = args.usize("slots", 2);
    let cfg = EngineConfig {
        pair: args.str("pair", "pair-a"),
        method: method.clone(),
        sched: Policy::parse(&sched),
        slots,
        workers: args.usize("workers", slots),
        ..EngineConfig::default()
    };
    println!(
        "booting engine: pair={} method={} sched={} workers={} ({} requests @ {:.1} req/s)",
        cfg.pair, method, sched, cfg.workers, items.len(), rate
    );
    let engine = Arc::new(Engine::start(cfg)?);

    // warm-up request (compiles the hot buckets before timing starts)
    let _ = engine.submit("warmup: 1 + 1 = ", 16).recv();

    let arrivals = poisson_arrivals(7, items.len(), rate);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for (item, &at) in items.iter().zip(&arrivals) {
        let wait = Duration::from_secs_f64(at).saturating_sub(t0.elapsed());
        std::thread::sleep(wait);
        let mut req = Request::new(0, item.text.clone(), item.max_new.min(96));
        req.id = pending.len() as u64 + 1000;
        req.category = item.category.clone();
        req.prompt = item.prompt.clone();
        pending.push((item.category.clone(), engine.submit_request(req)));
    }

    let mut got = 0;
    for (cat, rx) in pending {
        match rx.recv_timeout(Duration::from_secs(300)) {
            Ok(resp) => {
                got += 1;
                println!(
                    "  [{cat:<14}] {:>3} tok  queue {:>7.1} ms  decode {:>7.1} ms  m {:.2}",
                    resp.result.new_tokens().len(),
                    resp.queue_ns as f64 / 1e6,
                    resp.result.wall_ns as f64 / 1e6,
                    resp.result.mean_accepted(),
                );
            }
            Err(e) => println!("  [{cat}] FAILED: {e}"),
        }
    }

    println!("\n=== serving report ({got}/{} ok) ===", items.len());
    let (report, span_ns) = {
        let mut m = engine.metrics.lock().unwrap();
        (m.report(), m.span_ns)
    };
    println!("{report}");
    println!("{}", engine.stats.report(span_ns));
    if let Some(counts) = engine.bandit_counts() {
        println!("shared bandit: {} sessions, arm plays {:?}", engine.bandit_sessions(), counts);
    }
    Ok(())
}
