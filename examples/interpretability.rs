//! Interpretability demo (paper §4.3, Figs. 5-6): watch the sequence-level
//! UCB1 arm values evolve over drafting sessions and print an ASCII chart
//! of μ_i per arm.
//!
//!   cargo run --release --offline --example interpretability -- \
//!       [--pair pair-c] [--suite humaneval] [--backend pjrt|sim]

use anyhow::Result;

use tapout::harness::{load_suite, run_method, sim_suite, Backend};
use tapout::models::Manifest;
use tapout::runtime::Runtime;
use tapout::spec::MethodSpec;
use tapout::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_from(std::env::args().skip(1));
    let pair = args.str("pair", "pair-c");
    let suite = args.str("suite", "humaneval");
    let use_sim = args.str("backend", "pjrt") == "sim";

    let (backend, items) = if use_sim {
        (Backend::Sim { quality: 0.62, rel_cost: 1.0 / 24.0 }, sim_suite(&suite, 24, 96))
    } else {
        let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
        let runtime = Runtime::cpu()?;
        let items = load_suite(&manifest, &suite, 48)?;
        (Backend::pjrt(&manifest, &runtime, &pair)?, items)
    };

    let m = MethodSpec::parse("seq-ucb1", "artifacts").unwrap();
    let r = run_method(&backend, &items, &m, 128, true)?;
    let hist = &r.value_history;
    println!(
        "Seq UCB1 on {pair}/{suite}: {} sessions, {} arms\n",
        hist.len(),
        r.arm_names.len()
    );

    // ASCII progression: sample ~24 time points, one row per arm
    let steps: Vec<usize> = (0..24.min(hist.len()))
        .map(|i| i * hist.len().max(1) / 24.min(hist.len()).max(1))
        .collect();
    for (a, name) in r.arm_names.iter().enumerate() {
        let mut line = String::new();
        for &s in &steps {
            let v = hist[s][a];
            let glyph = match (v * 10.0) as i64 {
                i64::MIN..=1 => '▁',
                2..=3 => '▂',
                4..=4 => '▃',
                5..=5 => '▄',
                6..=6 => '▅',
                7..=7 => '▆',
                8..=8 => '▇',
                _ => '█',
            };
            line.push(glyph);
        }
        let last = hist.last().map(|h| h[a]).unwrap_or(0.0);
        println!("  {name:<22} {line}  μ = {last:.3}");
    }

    if let Some(last) = hist.last() {
        let mut ranked: Vec<(usize, f64)> = last.iter().copied().enumerate().collect();
        ranked.sort_by(|x, y| y.1.partial_cmp(&x.1).unwrap());
        println!(
            "\nfinal ranking: {}",
            ranked
                .iter()
                .map(|(i, v)| format!("{} ({v:.3})", r.arm_names[*i]))
                .collect::<Vec<_>>()
                .join("  >  ")
        );
        println!(
            "value spread: {:.3} (paper: large spread = one dominant strategy; tight cluster = continued exploration)",
            ranked[0].1 - ranked[ranked.len() - 1].1
        );
    }
    Ok(())
}
