//! Quickstart: load the pair-a artifacts, generate with vanilla Static-6
//! speculative decoding and with TapOut (sequence-level UCB1), and compare.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Requires `make artifacts` to have been run.

use anyhow::Result;

use tapout::models::{Manifest, ModelAssets, PjrtModel};
use tapout::runtime::Runtime;
use tapout::spec::{generate, GenConfig, MethodSpec, BOS};
use tapout::util::Rng;

fn main() -> Result<()> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let runtime = Runtime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());

    // one model pair, two serving slots' worth of state
    let (dspec, tspec) = manifest.pair("pair-a")?;
    let (dn, tn) = (dspec.name.clone(), tspec.name.clone());
    let mut draft = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &dn)?)?;
    let mut target = PjrtModel::new(ModelAssets::load(&runtime, &manifest, &tn)?)?;

    let prompts = [
        "def f1(a, b):\n    r = a + b",
        "q: who works on physics in rome? a:",
        "translate: red cat -> ",
    ];

    for method_name in ["static-6", "seq-ucb1"] {
        let method = MethodSpec::parse(method_name, "artifacts").unwrap();
        let mut ctrl = method.build(128)?;
        let mut rng = Rng::new(1);
        println!("\n=== {} ===", method.label());
        let mut tokens = 0usize;
        let mut ns = 0u64;
        let (mut acc, mut dr) = (0usize, 0usize);
        for p in prompts {
            let mut prompt = vec![BOS];
            prompt.extend(manifest.encode(p));
            let cfg = GenConfig { max_new: 96, ..GenConfig::default() };
            let r = generate(&mut draft, &mut target, &mut ctrl, &mut rng, &prompt, &cfg)?;
            println!(
                "  prompt {:?}\n  -> {:?}  (m {:.2}, accept {:.2})",
                p,
                manifest.decode(r.new_tokens()),
                r.mean_accepted(),
                r.acceptance_rate()
            );
            tokens += r.new_tokens().len();
            ns += r.wall_ns;
            acc += r.accepted();
            dr += r.drafted();
        }
        println!(
            "  total: {tokens} tokens, {:.1} tok/s, acceptance {:.2}",
            tokens as f64 / (ns as f64 / 1e9),
            acc as f64 / dr.max(1) as f64
        );
    }
    Ok(())
}
